"""PTQ embedding quantization (paper §4.2) — including the paper's own
quantitative claims: relative L2 error ~0.45% (int8) / ~7.8% (int4) on
normal-ish embedding tables, and int4 size = 31.25% of fp16."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_stub import given, hnp, settings, st

from repro.quant import (compression_ratio, dequantize_table, quantize_table,
                         quantized_lookup, relative_l2_error)


def test_paper_error_claims():
    """Paper §4.2: 'we observed 0.45% at int8 quantization, and 7.8% at
    int4' (relative L2 of the deviation).  Reproduce on a gaussian table of
    the production sub-embedding shape (R, 32)."""
    key = jax.random.PRNGKey(0)
    table = (0.02 * jax.random.normal(key, (50_000, 32))).astype(jnp.float16)
    err8 = relative_l2_error(table, quantize_table(table, 8))
    err4 = relative_l2_error(table, quantize_table(table, 4))
    assert 0.003 < err8 < 0.006, f"int8 rel L2 {err8} vs paper 0.0045"
    assert 0.06 < err4 < 0.10, f"int4 rel L2 {err4} vs paper 0.078"


def test_paper_size_claim():
    """int4: 32x4 + 16 + 16 = 160 bit vs 512 bit fp16 -> exactly 31.25%."""
    table = jnp.zeros((1024, 32), jnp.float16)
    qt = quantize_table(table, 4)
    assert compression_ratio(table, qt) == pytest.approx(0.3125)


@given(hnp.arrays(np.float32, (7, 32),
                  elements=st.floats(-1, 1, width=32)))
@settings(max_examples=50, deadline=None)
def test_quant_error_bound_property(table):
    """|x - dq(q(x))| <= scale/2 + fp16 rounding, per element, any input."""
    qt = quantize_table(jnp.asarray(table), 4)
    deq = np.asarray(dequantize_table(qt))
    scale = np.asarray(qt.scale, np.float32)
    span = np.abs(table).max(axis=1, keepdims=True) + 1
    tol = scale / 2 + 1e-3 * span       # half-step + fp16 scale/bias rounding
    assert (np.abs(deq - table) <= tol + 1e-6).all()


def test_quant_exact_at_extremes():
    """Row min and max are representable (codes 0 and 2^b-1) up to fp16."""
    table = jnp.asarray([[-1.0, 0.0, 0.5, 1.0] * 8], jnp.float32)
    qt = quantize_table(table, 4)
    deq = np.asarray(dequantize_table(qt))
    assert abs(deq[0].min() - (-1.0)) < 1e-3
    assert abs(deq[0].max() - 1.0) < 1e-3


def test_lookup_matches_full_dequant():
    key = jax.random.PRNGKey(1)
    table = 0.05 * jax.random.normal(key, (1000, 32))
    qt = quantize_table(table, 4)
    rows = jnp.asarray([0, 17, 999, 3, 3])
    got = quantized_lookup(qt, rows, use_kernel=True)
    full = dequantize_table(qt)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(full)[np.asarray(rows)])


def test_int8_better_than_int4():
    key = jax.random.PRNGKey(2)
    table = 0.02 * jax.random.normal(key, (5000, 32))
    e8 = relative_l2_error(table, quantize_table(table, 8))
    e4 = relative_l2_error(table, quantize_table(table, 4))
    assert e8 < e4 / 4


# ---------------------------------------------------------------------------
# degenerate rows: constant, single-row, and +-extreme-value tables must
# round-trip exactly at serving (fp16 scale/bias) precision — a constant
# row has scale == 0, which used to push every code through a 1e-12
# division instead of pinning them to 0.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("value", [0.0, 1.0, -3.25, 1e-5, 300.0])
def test_constant_rows_round_trip_exactly(bits, value):
    table = jnp.full((5, 32), value, jnp.float32)
    qt = quantize_table(table, bits)
    assert np.all(np.asarray(qt.scale) == 0)
    deq = np.asarray(dequantize_table(qt))
    np.testing.assert_array_equal(deq, np.float32(np.float16(value)))


@pytest.mark.parametrize("bits", [4, 8])
def test_single_row_table_round_trip(bits):
    row = jnp.asarray([[-1.0, 0.0, 0.5, 1.0] * 8], jnp.float32)
    qt = quantize_table(row, bits)
    deq = np.asarray(dequantize_table(qt))
    assert deq.shape == (1, 32)
    # min/max map to the end codes; everything within a half-step + fp16
    step = float(np.asarray(qt.scale)[0, 0])
    assert np.abs(deq - np.asarray(row)).max() <= step / 2 + 2e-3


@pytest.mark.parametrize("bits", [4, 8])
def test_extreme_values_stay_finite(bits):
    """Values beyond the fp16 range used to overflow scale/bias to inf and
    dequantize the whole row to inf/nan; now extrema clamp to +-65504."""
    table = jnp.asarray([[-1e9, 1e9] * 16,
                         [0.0, 1e30] * 16,
                         [-1e30, -5.0] * 16], jnp.float32)
    qt = quantize_table(table, bits)
    assert np.isfinite(np.asarray(qt.scale, np.float32)).all()
    assert np.isfinite(np.asarray(qt.bias, np.float32)).all()
    deq = np.asarray(dequantize_table(qt))
    assert np.isfinite(deq).all()
    # clamped extrema still land on the fp16 endpoints
    np.testing.assert_allclose(deq[0].min(), -65504.0, rtol=1e-3)
    np.testing.assert_allclose(deq[0].max(), 65504.0, rtol=1e-3)


@pytest.mark.parametrize("bits", [4, 8])
def test_mixed_degenerate_and_normal_rows(bits):
    key = jax.random.PRNGKey(5)
    normal = 0.05 * jax.random.normal(key, (3, 32))
    table = jnp.concatenate([jnp.zeros((1, 32)), normal,
                             jnp.full((1, 32), 2.5)], axis=0)
    qt = quantize_table(table, bits)
    deq = np.asarray(dequantize_table(qt))
    np.testing.assert_array_equal(deq[0], 0.0)
    np.testing.assert_array_equal(deq[-1], np.float32(np.float16(2.5)))
    err = np.abs(deq[1:-1] - np.asarray(normal))
    tol = np.asarray(qt.scale, np.float32)[1:-1] / 2 + 2e-3
    assert (err <= tol).all()
