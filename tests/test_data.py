"""Synthetic data pipeline: determinism, structure, dedup pattern."""
import numpy as np
import pytest

from repro.data.synthetic import (ACTIONS, POSITIVE_ACTIONS, DataConfig,
                                  SyntheticActivity)


@pytest.fixture(scope="module")
def data():
    return SyntheticActivity(DataConfig(n_users=100, n_items=500,
                                        n_topics=8, seq_len=32, seed=7))


def test_deterministic(data):
    b1 = next(data.pretrain_batches(8, 1, seed=3))
    b2 = next(data.pretrain_batches(8, 1, seed=3))
    for k in b1:
        np.testing.assert_array_equal(b1[k], b2[k])
    b3 = next(data.pretrain_batches(8, 1, seed=4))
    assert (b1["ids"] != b3["ids"]).any()


def test_pretrain_batch_shapes(data):
    b = next(data.pretrain_batches(8, 1))
    assert b["ids"].shape == (8, 32)
    assert b["actions"].shape == (8, 32)
    assert set(np.unique(b["actions"])) <= set(range(6))
    assert b["user_id"].shape == (8,)


def test_interest_structure_is_planted(data):
    """Items engaged positively should match user interests far above chance."""
    rng = np.random.RandomState(0)
    match, total = 0, 0
    for u in range(30):
        ev = data.user_events(u, 64, rng)
        interests = set(data.user_interests[u])
        for i, a in zip(ev["ids"], ev["actions"]):
            if a in POSITIVE_ACTIONS:
                match += data.item_topic[i] in interests
                total += 1
    assert total > 50
    assert match / total > 0.8      # vs ~3/8 by chance


def test_ranking_batch_dedup_pattern(data):
    b = next(data.ranking_batches(4, 8, 1))
    assert b["seq_ids"].shape[0] == 4
    assert b["cand_ids"].shape[0] == 32
    np.testing.assert_array_equal(b["inverse_idx"],
                                  np.repeat(np.arange(4), 8))
    assert b["labels"].shape == (32, 3)
    assert b["cand_age_days"].min() >= 0


def test_fresh_items_have_small_age(data):
    b = next(data.ranking_batches(8, 16, 1, fresh_prob=1.0))
    assert (b["cand_age_days"] < 28).all()
    assert data.is_fresh(b["cand_ids"]).all()


def test_labels_correlate_with_interest(data):
    """Save rate for interest-matching candidates >> non-matching."""
    b = next(data.ranking_batches(64, 8, 1, seed=9, fresh_prob=0.0))
    users = b["seq_user_id"][b["inverse_idx"]]
    match = np.array([
        data.item_topic[c] in set(data.user_interests[u])
        for c, u in zip(b["cand_ids"], users)])
    save = b["labels"][:, 0]
    assert save[match].mean() > save[~match].mean() + 0.2


def test_timestamps_monotonic(data):
    rng = np.random.RandomState(1)
    ev = data.user_events(0, 50, rng)
    assert (np.diff(ev["timestamps"]) > 0).all()
