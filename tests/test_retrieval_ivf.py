"""IVF-ANN retrieval route: coarse quantizer, cluster-contiguous layout,
slice scoring, the shared bitonic merge, and the engine/sharded wiring.

Parity strategy mirrors tests/test_retrieval.py: LATTICE corpora make all
fp32 arithmetic exact, so "full probe == the exact oracle bit-for-bit"
is a meaningful assertion.  The one IVF-specific caveat: the tie-break
row order is the PERMUTED row space (the physical layout the kernels
see), so oracles run on the permuted table and ids map back through
``row_map``.  Merge-helper parity needs no lattice — both merges select
from the same total order over the same operands, so they agree bitwise
on arbitrary floats.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels.ref import retrieval_topk_ref
from repro.kernels.retrieval_topk import (_SENTINEL_IDX, bitonic_topk_merge,
                                          retrieval_topk)
from repro.quant import quantize_table
from repro.retrieval import (CorpusScorer, IndexBuilder, ItemFilter,
                             ItemIndex, IVFScorer, ShardedRetriever,
                             build_ivf, filter_masks, ivf_route, ivf_topk,
                             kmeans)
from repro.retrieval.ivf import (SliceTable, assign_rows, dequant_rows,
                                 ivf_append, pad_for_slices, slice_masks)
from repro.retrieval.scorer import merge_topk
from repro.serving import ContextCache, RetrieveRequest, ServingEngine
from repro.serving.plan import request_key

from _hypothesis_stub import HAVE_HYPOTHESIS, given, settings, st
from test_retrieval import L, _lite_model, lattice_corpus


@pytest.fixture(scope="module")
def lite_model():
    return _lite_model()


def lattice_index(R, D=32, seed=0, start_id=0):
    qt, q = lattice_corpus(R, D, seed=seed)
    return ItemIndex(qt=qt, start_id=start_id, n_items=R), np.asarray(q)


def permuted_oracle(ividx, q, k, excl=None):
    """retrieval_topk_ref on the PERMUTED table — the row space the IVF
    tie-break contract is defined in.  ``excl``: (Q, R) bool, True =
    excluded (packed here into the oracle's bitmask words)."""
    mask = None
    if excl is not None:
        from repro.retrieval.filters import pack_bits
        mask = jnp.asarray(np.stack([pack_bits(e) for e in excl]))
    return retrieval_topk_ref(ividx.qt.packed, ividx.qt.scale,
                              ividx.qt.bias, jnp.asarray(q), k=k,
                              bits=ividx.bits, mask=mask)


# ---------------------------------------------------------------------------
# k-means + layout
# ---------------------------------------------------------------------------

def test_kmeans_assigns_nearest_centroid():
    rng = np.random.RandomState(0)
    x = rng.randn(500, 16).astype(np.float32)
    cents, assign = kmeans(x, 8, iters=5, seed=1, block_rows=128)
    assert cents.shape == (8, 16) and assign.shape == (500,)
    d = ((x[:, None, :] - cents[None, :, :]) ** 2).sum(-1)
    np.testing.assert_array_equal(assign, d.argmin(1))
    # deterministic in (x, seed); a different seed moves the centroids
    c2, a2 = kmeans(x, 8, iters=5, seed=1, block_rows=128)
    np.testing.assert_array_equal(cents, c2)
    np.testing.assert_array_equal(assign, a2)
    assert not np.array_equal(cents, kmeans(x, 8, iters=5, seed=2)[0])
    # assign_rows is the same nearest-centroid pass
    np.testing.assert_array_equal(assign_rows(x, cents, block_rows=64),
                                  d.argmin(1))


def test_kmeans_more_clusters_than_rows():
    x = np.eye(5, 8, dtype=np.float32)
    cents, assign = kmeans(x, 64, iters=3)
    assert cents.shape[0] == 5          # C clips to R
    assert len(np.unique(assign)) == 5


def test_build_ivf_layout_and_id_mapping():
    idx, _ = lattice_index(700, seed=4, start_id=30)
    ividx = build_ivf(idx, 10, seed=0)
    ivf = ividx.ivf
    assert ivf.n_clusters == 10 and ivf.n_items == 700
    assert ivf.n_clustered == 700 and ivf.appended_unclustered == 0
    # row_map is a permutation, inv_perm its inverse
    assert np.array_equal(np.sort(ivf.row_map), np.arange(700))
    np.testing.assert_array_equal(ivf.inv_perm[ivf.row_map], np.arange(700))
    # clusters are contiguous and the permutation is STABLE within each
    for c in range(10):
        seg = ivf.row_map[ivf.starts[c]:ivf.starts[c + 1]]
        np.testing.assert_array_equal(ivf.assignments[seg], c)
        assert np.all(np.diff(seg) > 0)
    assert ivf.starts[0] == 0 and ivf.starts[-1] == 700
    # the permuted table holds the original rows, rearranged
    np.testing.assert_array_equal(np.asarray(ividx.qt.packed),
                                  np.asarray(idx.qt.packed)[ivf.row_map])
    # centroid of each cluster routes to itself on its own members
    deq = dequant_rows(ividx.qt, 0, 700)
    np.testing.assert_array_equal(
        assign_rows(deq, ivf.centroids), ivf.assignments[ivf.row_map])
    # id mapping round-trips through the permutation
    rows = np.array([0, 5, 333, 699])
    np.testing.assert_array_equal(ividx.id_rows(ividx.item_ids(rows)), rows)
    assert ividx.item_ids(np.array([-1]))[0] == -1
    np.testing.assert_array_equal(ividx.id_rows([29, 730]), [-1, -1])


def test_ivf_npz_round_trip(tmp_path):
    idx, q = lattice_index(300, seed=7, start_id=5)
    ividx = build_ivf(idx, 6, seed=2)
    p = str(tmp_path / "ivf_index.npz")
    ividx.save(p)
    back = ItemIndex.load(p)
    assert back.ivf is not None
    for f in ("centroids", "starts", "row_map", "inv_perm", "assignments"):
        np.testing.assert_array_equal(getattr(back.ivf, f),
                                      getattr(ividx.ivf, f))
    assert back.ivf.n_clustered == ividx.ivf.n_clustered
    s0, r0 = IVFScorer(ividx, nprobe=2).topk(q, 20)
    s1, r1 = IVFScorer(back, nprobe=2).topk(q, 20)
    np.testing.assert_array_equal(r0, r1)
    np.testing.assert_array_equal(s0, s1)


# ---------------------------------------------------------------------------
# full / partial probe vs the exact oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("R,C,k", [(700, 10, 40), (257, 5, 17),
                                   (64, 3, 64)])
def test_full_probe_matches_oracle_bitwise(R, C, k):
    """nprobe == n_clusters visits every clustered row: the ONLY loss in
    the IVF route is cluster pruning, so full probe must equal the exact
    scorer on the permuted layout bit-for-bit, both merges."""
    idx, q = lattice_index(R, seed=R)
    ividx = build_ivf(idx, C, seed=1)
    rs, rr = permuted_oracle(ividx, q, k)
    for merge in ("bitonic", "topk"):
        s, r = IVFScorer(ividx, nprobe=C, merge=merge).topk(q, k)
        np.testing.assert_array_equal(r, np.asarray(rr))
        np.testing.assert_array_equal(s, np.asarray(rs))
    # CorpusScorer on the same permuted index agrees too (exact route
    # over an IVF index ignores the clustering entirely)
    s2, r2 = CorpusScorer(ividx, mode="fused", chunk_rows=128,
                          block_rows=32).topk(q, k)
    np.testing.assert_array_equal(np.asarray(r2), np.asarray(rr))


def test_partial_probe_equals_restricted_oracle():
    """A partial probe is EXACT over the visited clusters: masking every
    unvisited row out of the oracle reproduces the IVF result bitwise —
    recall loss comes solely from cluster pruning."""
    idx, q = lattice_index(900, seed=11)
    ividx = build_ivf(idx, 12, seed=3)
    ivf = ividx.ivf
    nprobe, k = 3, 30
    clusters = ivf_route(ivf.centroids, q, nprobe)
    assert clusters.shape == (q.shape[0], nprobe)
    # routing picks the nprobe nearest centroids, ascending cluster id
    d = ((q[:, None, :] - ivf.centroids[None]) ** 2).sum(-1)
    for qi in range(q.shape[0]):
        np.testing.assert_array_equal(
            np.sort(clusters[qi]), np.sort(np.argpartition(d[qi], nprobe)
                                           [:nprobe]))
        assert np.all(np.diff(clusters[qi]) > 0)
    s, r = IVFScorer(ividx, nprobe=nprobe).topk(q, k)
    # oracle restricted to the visited clusters, per query
    excl = np.ones((q.shape[0], 900), bool)
    for qi in range(q.shape[0]):
        for c in clusters[qi]:
            excl[qi, ivf.starts[c]:ivf.starts[c + 1]] = False
    rs, rr = permuted_oracle(ividx, q, k, excl)
    rr = np.where(np.asarray(rs) == -np.inf, -1, np.asarray(rr))
    np.testing.assert_array_equal(r, rr)
    np.testing.assert_array_equal(s, np.asarray(rs))
    # ... and is a subset of the unrestricted oracle's scores
    fs, _ = permuted_oracle(ividx, q, k)
    assert np.all(s <= np.asarray(fs) + 0)


def test_sentinels_k_exceeds_survivors():
    """k > rows in the visited clusters -> (-inf, -1) tails, ids -1."""
    idx, q = lattice_index(96, seed=5)
    ividx = build_ivf(idx, 8, seed=0)
    sc = IVFScorer(ividx, nprobe=1)
    k = 64                               # >> any single cluster
    s, r = sc.topk(q, k)
    filled = s > -np.inf
    assert filled.any() and not filled.all()
    np.testing.assert_array_equal(r[~filled], -1)
    assert np.all(np.diff(filled.astype(int), axis=1) <= 0)  # fills first
    _, ids = sc.retrieve(q, k)
    np.testing.assert_array_equal(ids[~filled], -1)
    # every visited cluster fully filtered -> all sentinels
    all_ids = np.arange(96) + ividx.start_id
    s2, r2 = sc.topk(q[:2], 10, filters=ItemFilter(exclude_ids=all_ids))
    np.testing.assert_array_equal(s2, -np.inf)
    np.testing.assert_array_equal(r2, -1)


def test_filter_pushdown_matches_masked_oracle():
    idx, q = lattice_index(600, seed=9, start_id=100)
    ividx = build_ivf(idx, 8, seed=4)
    rng = np.random.RandomState(0)
    filts = [ItemFilter(exclude_ids=100 + rng.choice(600, 250,
                                                     replace=False))
             for _ in range(q.shape[0])]
    C = ividx.ivf.n_clusters
    s, r = IVFScorer(ividx, nprobe=C).topk(q, 40, filters=filts)
    mask = filter_masks(filts, ividx)            # permuted row space
    from repro.retrieval.filters import unpack_bits
    excl = np.stack([unpack_bits(m, 600) for m in mask])
    rs, rr = permuted_oracle(ividx, q, 40, excl)
    rr = np.where(np.asarray(rs) == -np.inf, -1, np.asarray(rr))
    np.testing.assert_array_equal(r, rr)
    np.testing.assert_array_equal(s, np.asarray(rs))
    # excluded ids never surface
    _, ids = IVFScorer(ividx, nprobe=2).retrieve(q, 40, filters=filts)
    for qi in range(q.shape[0]):
        ex = set(np.asarray(filts[qi].exclude_ids).tolist())
        assert not ex & set(ids[qi][ids[qi] >= 0].tolist())


def test_recall_floor_widens_to_oracle():
    """With a 1.0 floor and a ladder reaching n_clusters, a filter that
    starves the base probe must widen until the result matches the
    (masked) exact oracle."""
    idx, q = lattice_index(400, seed=13)
    ividx = build_ivf(idx, 8, seed=1)
    sc = IVFScorer(ividx, nprobe=1, widen=3, recall_floor=1.0)
    assert sc.nprobe_levels == [1, 2, 4, 8]
    f = ItemFilter(exclude_ids=np.arange(380))   # only 20 survivors
    s, r = sc.topk(q, 15, filters=f)
    assert sc.widened > 0
    excl = np.zeros(400, bool)
    excl[ividx.id_rows(np.arange(380))] = True
    rs, rr = permuted_oracle(ividx, q, 15,
                             np.broadcast_to(excl, (q.shape[0], 400)))
    np.testing.assert_array_equal(r, np.asarray(rr))
    np.testing.assert_array_equal(s, np.asarray(rs))


# ---------------------------------------------------------------------------
# append without re-clustering
# ---------------------------------------------------------------------------

def test_ivf_append_unclustered_tail():
    idx, q = lattice_index(500, seed=21)
    ividx = build_ivf(idx, 6, seed=0)
    qt2, _ = lattice_corpus(80, 32, seed=99)
    new = dequant_rows(qt2, 0, 80)
    grown_ivf = ivf_append(ividx.ivf, new)
    assert grown_ivf.n_items == 580 and grown_ivf.appended_unclustered == 80
    assert grown_ivf.n_clustered == 500
    # clusters untouched; tail is identity-mapped
    np.testing.assert_array_equal(grown_ivf.starts, ividx.ivf.starts)
    np.testing.assert_array_equal(grown_ivf.row_map[:500],
                                  ividx.ivf.row_map)
    np.testing.assert_array_equal(grown_ivf.row_map[500:],
                                  np.arange(500, 580))
    # appended rows get nearest-centroid assignments WITHOUT re-clustering
    np.testing.assert_array_equal(
        grown_ivf.assignments[500:],
        assign_rows(new, ividx.ivf.centroids))
    np.testing.assert_array_equal(grown_ivf.assignments[:500],
                                  ividx.ivf.assignments)


def test_append_then_retrieve_matches_exact(lite_model):
    """builder.append on an IVF index: the tail is scanned exactly, so a
    full probe over the grown index equals the exact scorer on it."""
    model, params = lite_model
    builder = IndexBuilder(model, params, batch_size=256)
    ividx = build_ivf(builder.build(0, 400), 6, seed=0)
    grown = builder.append(ividx, 60)
    assert grown.ivf.appended_unclustered == 60
    assert grown.n_items == 460
    q = builder.item_embeddings(np.arange(400, 460))[:4]
    sc = IVFScorer(grown, nprobe=grown.ivf.n_clusters)
    s, ids = sc.retrieve(q, 10)
    s_ref, ids_ref = CorpusScorer(grown, mode="ref").retrieve(
        jnp.asarray(q), 10)
    np.testing.assert_array_equal(ids, np.asarray(ids_ref))
    np.testing.assert_allclose(s, np.asarray(s_ref), atol=1e-6)
    # each tail item surfaces for its own embedding (int4 rounding can
    # cost it rank 1 to a near-duplicate, but never the top-10)
    assert all(400 + i in ids[i] for i in range(4))
    # rebuild folds the tail back in
    rebuilt = build_ivf(grown, 6, seed=0)
    assert rebuilt.ivf.appended_unclustered == 0
    assert rebuilt.ivf.n_clustered == 460


# ---------------------------------------------------------------------------
# ONE merge order, two implementations (host + device)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("K,N", [(1, 1), (7, 13), (16, 16), (32, 100),
                                 (64, 5), (100, 37)])
def test_bitonic_merge_matches_host_merge(K, N):
    """bitonic_topk_merge (device) and merge_topk (host) realize the same
    (score desc, index asc) total order -> bitwise equal top-k on
    ARBITRARY floats, including duplicates, -inf, and sentinel slots.

    merge_topk's contract wants each partial pre-sorted with ascending
    row ranges across groups (chunk order), so build operands that way —
    the bitonic network needs neither, which is the point of the test."""
    rng = np.random.RandomState(K * 131 + N)

    def grp(scores, lo, hi):
        idx = np.where(scores == -np.inf, _SENTINEL_IDX,
                       rng.randint(lo, hi, scores.shape)).astype(np.int32)
        order = np.lexsort((idx, -scores), axis=-1)
        return (np.take_along_axis(scores, order, -1).astype(np.float32),
                np.take_along_axis(idx, order, -1))

    for trial in range(4):
        cs, ci = grp(rng.choice([-np.inf, -1.5, 0.0, 0.25, 7.5], (3, K)),
                     0, 50)
        bs, bi = grp(rng.choice([-np.inf, -1.5, 0.25, 2.0, 7.5], (3, N)),
                     50, 100)
        ds, di = bitonic_topk_merge(jnp.asarray(cs),
                                    jnp.asarray(ci), jnp.asarray(bs),
                                    jnp.asarray(bi), k=K)
        hs, hi = merge_topk([cs.astype(np.float32), bs.astype(np.float32)],
                            [ci, bi], K)
        hi = np.where(hs == -np.inf, _SENTINEL_IDX, hi)
        di_n = np.asarray(di)
        np.testing.assert_array_equal(np.asarray(ds), hs)
        # compare only slots carrying real entries; both use the same
        # sentinel for empty slots
        np.testing.assert_array_equal(np.where(hs == -np.inf,
                                               _SENTINEL_IDX, di_n), hi)


@pytest.mark.parametrize("R,k,block_rows", [(777, 33, 64), (4096, 100, 256)])
def test_kernel_merge_modes_bit_identical(R, k, block_rows):
    """Acceptance: the bitonic carry merge replaces the lexicographic
    lax.sort merge with bit-identical results — exact path..."""
    qt, q = lattice_corpus(R, 32, seed=R + 1)
    outs = [retrieval_topk(qt.packed, qt.scale, qt.bias, q, k=k,
                           block_rows=block_rows, merge=m)
            for m in ("bitonic", "sort")]
    np.testing.assert_array_equal(np.asarray(outs[0][0]),
                                  np.asarray(outs[1][0]))
    np.testing.assert_array_equal(np.asarray(outs[0][1]),
                                  np.asarray(outs[1][1]))


def test_ivf_merge_modes_bit_identical():
    """... and IVF path (lax.scan over slices vs flat lax.top_k)."""
    idx, q = lattice_index(640, seed=31)
    ividx = build_ivf(idx, 8, seed=2)
    tab = SliceTable(ividx.ivf, 64)
    S = tab.slots(3)
    off, val = tab.gather(ivf_route(ividx.ivf.centroids, q, 3), S)
    pk, sc, bs = pad_for_slices(ividx.qt, 64)
    outs = [ivf_topk(jnp.asarray(q), pk, sc, bs, off, val, k=25,
                     slice_rows=64, merge=m) for m in ("bitonic", "topk")]
    np.testing.assert_array_equal(np.asarray(outs[0][0]),
                                  np.asarray(outs[1][0]))
    np.testing.assert_array_equal(np.asarray(outs[0][1]),
                                  np.asarray(outs[1][1]))


# ---------------------------------------------------------------------------
# ShardedRetriever IVF route
# ---------------------------------------------------------------------------

def test_sharded_ivf_matches_scorer():
    idx, q = lattice_index(800, seed=17)
    ividx = build_ivf(idx, 10, seed=5)
    sh = ShardedRetriever(ividx, chunk_rows=256, block_rows=32)
    for nprobe in (2, 10):
        s_ref, r_ref = IVFScorer(ividx, nprobe=nprobe).topk(q, 30)
        s, r = sh.topk(q, 30, route="ivf", nprobe=nprobe)
        np.testing.assert_array_equal(r, r_ref)
        np.testing.assert_array_equal(s, s_ref)
    # filtered
    f = ItemFilter(exclude_ids=np.arange(0, 300))
    s_ref, r_ref = IVFScorer(ividx, nprobe=4).topk(q, 30, filters=f)
    s, r = sh.topk(q, 30, route="ivf", nprobe=4, filters=f)
    np.testing.assert_array_equal(r, r_ref)
    # route validation
    plain, _ = lattice_index(100)
    with pytest.raises(ValueError, match="ivf"):
        ShardedRetriever(plain, chunk_rows=64).topk(q, 5, route="ivf")


# ---------------------------------------------------------------------------
# ServingEngine IVF route
# ---------------------------------------------------------------------------

def _mk_retrieve(seed, k=20, **kw):
    r = np.random.RandomState(seed)
    return RetrieveRequest(seq_ids=r.randint(0, 500, L),
                           seq_actions=r.randint(0, 6, L),
                           seq_surfaces=r.randint(0, 3, L), k=k, **kw)


@pytest.fixture(scope="module")
def ivf_engine(lite_model):
    model, params = lite_model
    builder = IndexBuilder(model, params, batch_size=256)
    ividx = build_ivf(builder.build(0, 1000), 12, seed=3)
    engine = ServingEngine(model, params, max_unique=4, max_candidates=16,
                           cache=ContextCache(capacity=64))
    # base 2 with widen=3 -> levels {2, 4, 8, 12}; 12 == C == full probe
    engine.attach_index(ividx, k=20, chunk_rows=256, ivf_nprobe=2,
                        ivf_widen=3)
    tel = engine.warmup()
    assert tel["compiles_after_warmup"] == 0
    return engine, builder, ividx


def _engine_emb(engine, req):
    e, _ = engine._user_embeddings([req], [request_key(req)])
    return e


def test_engine_mixed_stream_zero_recompiles(ivf_engine):
    """Acceptance: a mixed exact + IVF + filtered stream runs entirely on
    warmed executors.  Cross-route parity: ids bitwise (scores only to
    1e-6 — different batch buckets compile different XLA programs whose
    reductions differ in the last bit on non-lattice data)."""
    engine, _, ividx = ivf_engine
    reqs = [_mk_retrieve(1), _mk_retrieve(1, route="ivf"),
            _mk_retrieve(2, route="ivf", nprobe=5),
            _mk_retrieve(3, exclude_ids=np.arange(0, 50)),
            _mk_retrieve(3, route="ivf", exclude_ids=np.arange(0, 50)),
            _mk_retrieve(4, route="ivf", nprobe=12), _mk_retrieve(4),
            _mk_retrieve(6, route="ivf")]
    res = engine.retrieve(reqs)
    assert engine.registry.compiles_after_warmup == 0, \
        engine.registry.telemetry()
    # full probe == exact route on the same flushed embedding
    np.testing.assert_array_equal(res[5][0], res[6][0])
    np.testing.assert_allclose(res[5][1], res[6][1], atol=1e-6)
    # partial probe parity vs the standalone scorer on the SAME embedding
    sc2 = IVFScorer(ividx, nprobe=2, slice_rows=engine._ivf["sr"])
    _, ids_ref = sc2.retrieve(_engine_emb(engine, _mk_retrieve(1)), 20)
    np.testing.assert_array_equal(res[1][0], ids_ref[0])
    # nprobe=5 serves at the next level up (8)
    sc8 = IVFScorer(ividx, nprobe=8, slice_rows=engine._ivf["sr"])
    _, ids_ref = sc8.retrieve(_engine_emb(engine, _mk_retrieve(2)), 20)
    np.testing.assert_array_equal(res[2][0], ids_ref[0])
    # filtered pushdown
    _, ids_ref = sc2.retrieve(
        _engine_emb(engine, _mk_retrieve(3)), 20,
        filters=ItemFilter(exclude_ids=np.arange(0, 50)))
    np.testing.assert_array_equal(res[4][0], ids_ref[0])
    assert not np.any(np.isin(res[4][0], np.arange(50)) & (res[4][0] >= 0))
    # obs counters moved
    st_ivf = engine.stats()["retrieval"]["ivf"]
    assert st_ivf["clusters_probed"] > 0 and st_ivf["rows_scanned"] > 0
    assert st_ivf["nprobe_levels"] == [2, 4, 8, 12]
    text = engine.obs.prometheus_text()
    assert "repro_serving_retrieval_clusters_probed_total" in text
    assert "repro_serving_retrieval_rows_scanned_total" in text
    assert "repro_serving_retrieval_ivf_fill" in text


def test_engine_append_reattach_keeps_warm(ivf_engine):
    """Acceptance (satellite 1): append -> re-attach -> IVF retrieve with
    ZERO fresh compiles; the unclustered tail is reachable and counted."""
    engine, builder, ividx = ivf_engine
    grown = builder.append(ividx, 80)
    assert grown.ivf.appended_unclustered == 80
    engine.attach_index(grown, k=20, chunk_rows=256, ivf_nprobe=2,
                        ivf_widen=3)
    res = engine.retrieve([_mk_retrieve(7, route="ivf", nprobe=12),
                           _mk_retrieve(7)])
    assert engine.registry.compiles_after_warmup == 0, \
        engine.registry.telemetry()
    np.testing.assert_array_equal(res[0][0], res[1][0])
    np.testing.assert_allclose(res[0][1], res[1][1], atol=1e-6)
    st_ivf = engine.stats()["retrieval"]["ivf"]
    assert st_ivf["appended_unclustered"] == 80
    # tail items surface through the IVF route
    tail_emb = builder.item_embeddings(np.arange(1000, 1080))[:2]
    _, ids = IVFScorer(grown, nprobe=1).retrieve(tail_emb, 5)
    assert np.any(ids >= 1000)
    # restore the module-scoped engine for later tests
    engine.attach_index(ividx, k=20, chunk_rows=256, ivf_nprobe=2,
                        ivf_widen=3)
    assert engine.registry.compiles_after_warmup == 0


def test_engine_recall_floor_widens(lite_model):
    model, params = lite_model
    ividx = build_ivf(
        IndexBuilder(model, params, batch_size=256).build(0, 500), 8,
        seed=0)
    engine = ServingEngine(model, params, max_unique=2, max_candidates=8,
                           cache=ContextCache(capacity=16))
    engine.attach_index(ividx, k=20, chunk_rows=256, ivf_nprobe=1,
                        ivf_widen=3, ivf_recall_floor=1.0)
    engine.warmup()
    res = engine.retrieve([_mk_retrieve(8, route="ivf",
                                        exclude_ids=np.arange(0, 480))])
    assert engine.registry.compiles_after_warmup == 0, \
        engine.registry.telemetry()
    st_ivf = engine.stats()["retrieval"]["ivf"]
    assert st_ivf["widened"] > 0
    ids = res[0][0]
    assert np.all((ids >= 480) | (ids == -1))


def test_engine_route_validation(ivf_engine, lite_model):
    engine, _, _ = ivf_engine
    with pytest.raises(ValueError, match="route"):
        engine.submit(_mk_retrieve(1, route="bogus"))
    with pytest.raises(ValueError, match="nprobe"):
        engine.submit(_mk_retrieve(1, nprobe=4))     # exact route
    with pytest.raises(ValueError, match="nprobe"):
        engine.submit(_mk_retrieve(1, route="ivf", nprobe=0))
    # ivf route against a non-IVF index
    model, params = lite_model
    plain = IndexBuilder(model, params, batch_size=256).build(0, 200)
    e2 = ServingEngine(model, params, max_unique=2, max_candidates=8)
    e2.attach_index(plain, k=8, chunk_rows=256)
    with pytest.raises(ValueError, match="ivf"):
        e2.submit(_mk_retrieve(1, k=8, route="ivf"))


# ---------------------------------------------------------------------------
# property-style: random corpora/filters -> IVF subset of the exact oracle
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 6), st.integers(1, 40))
def test_property_ivf_subset_of_oracle(seed, nprobe, k):
    """For ANY corpus/filter draw: (a) every id the IVF route returns is
    in the masked exact oracle's ranking with the identical score,
    (b) full probe recall@k == 1.0, (c) with recall_floor=1.0 and a
    ladder reaching n_clusters the widened result matches the oracle."""
    rng = np.random.RandomState(seed)
    R = int(rng.randint(60, 400))
    idx, q = lattice_index(R, seed=seed % 10007)
    q = q[:3]
    ividx = build_ivf(idx, int(rng.randint(2, 9)), seed=seed % 97)
    C = ividx.ivf.n_clusters
    k = min(k, R)
    filts = None
    excl = np.zeros((3, R), bool)
    if rng.rand() < 0.6:
        filts = [ItemFilter(exclude_ids=rng.choice(R, rng.randint(1, R),
                                                   replace=False))
                 for _ in range(3)]
        for qi, f in enumerate(filts):
            excl[qi, ividx.id_rows(np.asarray(f.exclude_ids))] = True
    rs, rr = permuted_oracle(ividx, q, k, excl)
    rs, rr = np.asarray(rs), np.asarray(rr)
    rr = np.where(rs == -np.inf, -1, rr)
    # (a) subset with identical scores
    s, r = IVFScorer(ividx, nprobe=min(nprobe, C)).topk(q, k, filters=filts)
    deq = dequant_rows(ividx.qt, 0, R)
    for qi in range(3):
        got = r[qi][r[qi] >= 0]
        assert not set(got.tolist()) & set(
            np.flatnonzero(excl[qi]).tolist())
        exact = deq[got] @ q[qi]
        np.testing.assert_array_equal(s[qi][r[qi] >= 0], exact)
    # (b) full probe == oracle
    s_f, r_f = IVFScorer(ividx, nprobe=C).topk(q, k, filters=filts)
    np.testing.assert_array_equal(r_f, rr)
    np.testing.assert_array_equal(s_f, rs)
    # (c) the recall-floor ladder: widening never hurts (a wider probe's
    # top-k dominates elementwise), and it halts only once every slot is
    # filled (fill is the floor's proxy) or the probe reaches ALL
    # clusters — in which case the result IS the oracle
    sc_w = IVFScorer(ividx, nprobe=min(nprobe, C), widen=5,
                     recall_floor=1.0)
    assert sc_w.nprobe_levels[-1] == C
    s_w, r_w = sc_w.topk(q, k, filters=filts)
    assert np.all(s_w >= s)
    if not np.all(s_w > -np.inf):       # ladder exhausted -> full probe
        np.testing.assert_array_equal(r_w, rr)
        np.testing.assert_array_equal(s_w, rs)
