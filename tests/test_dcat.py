"""DCAT (paper §4.1) — the centerpiece correctness suite.

1. EQUIVALENCE: DCAT (dedup context + crossing) == full self-attention over
   the un-deduplicated batch with candidates appended, for every backbone
   family (dense / gpt2 / ssm / hybrid / moe).
2. Ψ/Ψ⁻¹ invertibility (hypothesis property).
3. skip-last-self-attn: crossing output bit-identical.
4. rotate-replace == concat with the oldest slots masked.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_stub import given, settings, st

from repro.configs import smoke_config
from repro.core.dcat import DCAT, DCATOptions, dedup, dedup_inverse, dedup_stats
from repro.models.config import get_config
from repro.models.transformer import TransformerBody
from repro.nn.attention import Attention, attend

BACKBONES = ["pinfm-20b", "qwen3-4b", "mamba2-2.7b", "recurrentgemma-2b",
             "mixtral-8x7b"]


def _setup(name, key=0):
    cfg = smoke_config(get_config(name)).replace(
        ssm_chunk=2, window=None, capacity_factor=8.0)
    body = TransformerBody(cfg)
    p = body.init(jax.random.PRNGKey(key))
    Bu, L, Sc = 3, 12, 2
    x_u = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (Bu, L, cfg.d_model))
    inv = np.array([0, 0, 0, 1, 1, 2, 2, 2], np.int32)
    x_c = 0.1 * jax.random.normal(jax.random.PRNGKey(2),
                                  (len(inv), Sc, cfg.d_model))
    return body, p, x_u, x_c, inv, L


@pytest.mark.parametrize("name", BACKBONES)
def test_dcat_equivalence(name):
    body, p, x_u, x_c, inv, L = _setup(name)
    dcat = DCAT(body)
    _, _, ctxs = dcat.context(p, x_u)
    y_dcat, _ = dcat.crossing(p, x_c, inv, ctxs, ctx_len=L)
    y_ref, _ = dcat.reference_scores(p, x_u, x_c, inv)
    np.testing.assert_allclose(np.asarray(y_dcat), np.asarray(y_ref),
                               atol=5e-5)


def test_skip_last_identical_crossing():
    body, p, x_u, x_c, inv, L = _setup("pinfm-20b")
    base = DCAT(body)
    _, _, ctxs = base.context(p, x_u)
    y0, _ = base.crossing(p, x_c, inv, ctxs, ctx_len=L)
    sl = DCAT(body, DCATOptions(skip_last_self_attn=True))
    _, _, ctxs_sl = sl.context(p, x_u, serving=True)
    y1, _ = sl.crossing(p, x_c, inv, ctxs_sl, ctx_len=L)
    np.testing.assert_array_equal(np.asarray(y0), np.asarray(y1))


def test_rotate_replace_equals_masked_concat():
    key = jax.random.PRNGKey(0)
    att = Attention(64, 4, 2, 16, rope=True)
    p = att.init(key)
    B, L, Sc = 3, 16, 2
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, Sc, 64))
    k_ctx = jax.random.normal(jax.random.fold_in(key, 2), (B, L, 2, 16))
    v_ctx = jax.random.normal(jax.random.fold_in(key, 3), (B, L, 2, 16))
    y_rot = att.cross(p, x, k_ctx, v_ctx, rotate_replace=True)

    pos_q = jnp.broadcast_to(jnp.arange(L, L + Sc), (B, Sc))
    q, k, v = att.qkv(p, x, pos_q)
    q4 = q.reshape(B, Sc, att.n_heads, att.head_dim)
    k_full = jnp.concatenate([k_ctx, k], 1)
    v_full = jnp.concatenate([v_ctx, v], 1)
    k_pos = jnp.broadcast_to(jnp.arange(L + Sc), (B, L + Sc))
    k_valid = jnp.broadcast_to(jnp.arange(L + Sc) >= Sc, (B, L + Sc))
    o = attend(q4, k_full, v_full, q_pos=pos_q, k_pos=k_pos, causal=True,
               k_valid=k_valid)
    y_ref = att.out(p, o.reshape(q.shape))
    np.testing.assert_allclose(np.asarray(y_rot), np.asarray(y_ref),
                               atol=1e-5)


def test_ctx_rotate_crossing_matches_inplace_rotation():
    """The pre-rotated fixed-L serving layout (ctx_rotate + rotated
    crossing) scores the same candidates as the per-call in-place rotation
    — same key SET {surviving ctx slots, candidate KV}, only the slot
    order differs, so results agree to fp summation order."""
    from repro.core.dcat import ctx_rotate
    body, p, x_u, x_c, inv, L = _setup("pinfm-20b")
    Sc = x_c.shape[1]
    dcat = DCAT(body, DCATOptions(rotate_replace=True))
    _, _, ctxs = dcat.context(p, x_u)
    y_inplace, _ = dcat.crossing(p, x_c, inv, ctxs, ctx_len=L)
    rot = ctx_rotate(ctxs, Sc, L)
    # every KV leaf lost its oldest Sc slots; nothing else changed
    for a, b in zip(jax.tree.leaves(ctxs), jax.tree.leaves(rot)):
        if a.ndim >= 4 and a.shape[-3] == L:
            assert b.shape[-3] == L - Sc
            np.testing.assert_array_equal(np.asarray(a[..., Sc:, :, :]),
                                          np.asarray(b))
        else:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    y_rot, _ = dcat.crossing(p, x_c, inv, rot, ctx_len=L, rotated=True)
    np.testing.assert_allclose(np.asarray(y_inplace), np.asarray(y_rot),
                               atol=5e-5)


def test_ctx_rotate_requires_rotate_replace():
    from repro.core.dcat import ctx_rotate
    body, p, x_u, x_c, inv, L = _setup("pinfm-20b")
    dcat = DCAT(body)                      # rotate_replace=False
    _, _, ctxs = dcat.context(p, x_u)
    rot = ctx_rotate(ctxs, x_c.shape[1], L)
    with pytest.raises(AssertionError, match="rotate_replace"):
        dcat.crossing(p, x_c, inv, rot, ctx_len=L, rotated=True)


def test_dcat_gather_idx_kernel_path_matches_xla():
    """Attention.cross with gather_idx (fused-gather semantics) == take+attend."""
    key = jax.random.PRNGKey(0)
    att_x = Attention(64, 4, 2, 16, rope=True, impl="xla")
    att_p = Attention(64, 4, 2, 16, rope=True, impl="pallas")
    p = att_x.init(key)
    Bu, L, Sc, Bc = 3, 32, 2, 8
    inv = jnp.asarray(np.random.RandomState(0).randint(0, Bu, Bc), jnp.int32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (Bc, Sc, 64))
    k_u = jax.random.normal(jax.random.fold_in(key, 2), (Bu, L, 2, 16))
    v_u = jax.random.normal(jax.random.fold_in(key, 3), (Bu, L, 2, 16))
    y_x = att_x.cross(p, x, k_u, v_u, gather_idx=inv)
    y_k = att_p.cross(p, x, k_u, v_u, gather_idx=inv)
    np.testing.assert_allclose(np.asarray(y_x), np.asarray(y_k), atol=2e-5)


# -- Ψ properties -------------------------------------------------------------

@given(st.lists(st.integers(0, 4), min_size=1, max_size=24))
@settings(max_examples=50, deadline=None)
def test_dedup_invertible_property(pattern):
    """Ψ⁻¹(Ψ(x)) == x for arbitrary duplication patterns."""
    rows = np.asarray(pattern)[:, None] * np.ones((1, 5), np.int64)
    unique, inverse = dedup(rows)
    assert len(unique) == len(set(pattern))
    np.testing.assert_array_equal(np.asarray(dedup_inverse(unique, inverse)),
                                  rows)
    # first-occurrence order: unique rows appear in order of first appearance
    firsts = []
    seen = set()
    for v in pattern:
        if v not in seen:
            seen.add(v)
            firsts.append(v)
    np.testing.assert_array_equal(unique[:, 0], firsts)


def test_dedup_stats():
    s = dedup_stats(np.array([0, 0, 0, 1, 1, 2]))
    assert s["candidates"] == 6 and s["unique_users"] == 3
    assert s["dedup_ratio"] == 2.0
