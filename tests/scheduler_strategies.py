"""Strategy module for the property-based scheduler suite.

Generates randomized scheduler CASES — lane policies, request mixes with
priorities, and flush/poll/shed interleavings — in two interchangeable
ways:

  * :func:`case_strategy` — a real ``hypothesis`` strategy (structured
    generation, so shrinking works on the case structure), used when
    hypothesis is installed (the CI property leg);
  * :func:`random_case` — a seeded stdlib-``random`` generator producing
    the SAME case shapes, so the deterministic fallback loop runs the full
    property suite (>= 200 cases) even in containers without hypothesis.

Determinism matters more than realism here: deadline budgets are drawn
from {None, 0.0, HUGE} only — a 0 ms budget sheds every sheddable request
at pickup (any queue wait is > 0), a huge one sheds nothing — so a case's
shed outcome never depends on wall-clock timing.

The checker (:func:`run_case`) executes a case against a real
``RequestScheduler`` over a recording fake flush function and asserts the
scheduler invariants:

  1. every future resolves EXACTLY ONCE — a result or an exception,
     never neither (hang) or a silent drop;
  2. per-caller order: within each lane, requests are served in
     submission order (across flushes and within each flush);
  3. no request is both shed and served;
  4. shed only when over budget: every ShedError names a lane whose
     policy makes that shed possible (deadline -> finite ``shed_ms`` and
     sheddable priority; admission -> ``max_queue`` set), and
     protected-priority requests are never shed;
  5. results route to the right future (each future resolves to its own
     request's tag).
"""
import dataclasses
import random
from typing import Dict, List, Tuple

from _hypothesis_stub import HAVE_HYPOTHESIS, st

from repro.serving.plan import LanePolicy
from repro.serving.scheduler import RequestScheduler, ShedError

LANES = ("rank", "retrieve", "two_stage")
HUGE_MS = 1e9           # a budget nothing can exceed within one test

# ops: ("submit", lane, priority, cost) | ("flush", lane-or-None)
#    | ("poll",) | ("shed",) | ("result", k) — resolve the k-th oldest
#      outstanding future via its targeted result() flush
Op = Tuple


@dataclasses.dataclass
class FakeRequest:
    """Untyped scheduler payload: ``cand_ids`` feeds request_cost,
    ``priority`` feeds the shed paths, ``uid`` routes results back."""
    uid: int
    lane: str
    priority: int
    cand_ids: List[int]


@dataclasses.dataclass
class Case:
    policies: Dict[str, LanePolicy]
    ops: List[Op]
    isolate_lanes: bool
    max_requests: int           # scheduler-wide default lane threshold


def _policy_from(draw_int, draw_choice) -> LanePolicy:
    """One lane policy from two primitive draws (shared by the hypothesis
    and the seeded generator so both cover the same space)."""
    return LanePolicy(
        max_requests=draw_choice([None, 1, 2, 3, 5]),
        max_candidates=draw_choice([None, None, 4, 8]),
        shed_ms=draw_choice([None, None, 0.0, HUGE_MS]),
        shed_max_priority=draw_int(0, 1),
        max_queue=draw_choice([None, None, 1, 2, 3]),
    )


def random_case(seed: int) -> Case:
    """The seeded fallback generator: same case space as
    :func:`case_strategy`, fully deterministic per seed."""
    rng = random.Random(seed)
    draw_int = rng.randint
    draw_choice = rng.choice
    lanes = tuple(LANES[:rng.randint(1, len(LANES))])
    policies = {lane: _policy_from(draw_int, draw_choice)
                for lane in lanes if rng.random() < 0.8}
    ops: List[Op] = []
    for _ in range(rng.randint(5, 40)):
        roll = rng.random()
        if roll < 0.65:
            ops.append(("submit", rng.choice(lanes), rng.randint(0, 2),
                        rng.randint(1, 4)))
        elif roll < 0.80:
            ops.append(("flush", rng.choice(lanes + (None,))))
        elif roll < 0.88:
            ops.append(("poll",))
        elif roll < 0.95:
            ops.append(("shed",))
        else:
            ops.append(("result", rng.randint(0, 5)))
    return Case(policies=policies, ops=ops,
                isolate_lanes=rng.random() < 0.8,
                max_requests=rng.choice([2, 4, 100]))


if HAVE_HYPOTHESIS:
    @st.composite
    def case_strategy(draw):
        lanes = tuple(draw(st.sampled_from(
            [LANES[:1], LANES[:2], LANES])))
        draw_int = lambda lo, hi: draw(st.integers(lo, hi))
        draw_choice = lambda xs: draw(st.sampled_from(xs))
        policies = {lane: _policy_from(draw_int, draw_choice)
                    for lane in lanes if draw(st.booleans())}
        op = st.one_of(
            st.tuples(st.just("submit"), st.sampled_from(lanes),
                      st.integers(0, 2), st.integers(1, 4)),
            st.tuples(st.just("flush"),
                      st.sampled_from(lanes + (None,))),
            st.tuples(st.just("poll")),
            st.tuples(st.just("shed")),
            st.tuples(st.just("result"), st.integers(0, 5)),
        )
        ops = draw(st.lists(op, min_size=1, max_size=40))
        return Case(policies=policies, ops=ops,
                    isolate_lanes=draw(st.booleans()),
                    max_requests=draw(st.sampled_from([2, 4, 100])))
else:                                   # pragma: no cover - hypothesis leg
    def case_strategy():
        return None


def run_case(case: Case) -> None:
    """Execute one case on a real scheduler + fake flush_fn and assert
    every scheduler invariant (see module docstring)."""
    calls: List[List[FakeRequest]] = []

    def flush_fn(batch):
        calls.append(list(batch))
        return [("ok", r.uid) for r in batch]

    sched = RequestScheduler(
        flush_fn, max_requests=case.max_requests,
        max_wait_s=HUGE_MS,             # poll() never flushes by age here
        lane_fn=lambda r: r.lane,
        lane_policies=case.policies,
        isolate_lanes=case.isolate_lanes)

    futures: List = []
    requests: List[FakeRequest] = []
    uid = 0
    for op in case.ops:
        if op[0] == "submit":
            _, lane, prio, cost = op
            r = FakeRequest(uid=uid, lane=lane, priority=prio,
                            cand_ids=list(range(cost)))
            uid += 1
            requests.append(r)
            futures.append(sched.submit(r))
        elif op[0] == "flush":
            sched.flush(lane=op[1])
        elif op[0] == "poll":
            sched.poll()
        elif op[0] == "shed":
            sched.shed_expired()
        elif op[0] == "result":
            outstanding = [f for f in futures if not f.done()]
            if outstanding:
                try:
                    outstanding[op[1] % len(outstanding)].result()
                except ShedError:
                    pass
    sched.flush()

    # -- invariant 1: exactly-once resolution, no hangs, no silent drops --
    served_uids: List[int] = [r.uid for b in calls for r in b]
    shed_uids: List[int] = []
    for r, f in zip(requests, futures):
        assert f.done(), f"request {r.uid} neither served nor shed (hang)"
        try:
            value = f.result()
        except ShedError as e:
            shed_uids.append(r.uid)
            # -- invariant 4: shed only when over budget ------------------
            pol = case.policies.get(r.lane, LanePolicy())
            assert e.lane == r.lane
            assert r.priority <= pol.shed_max_priority, \
                f"protected request {r.uid} (prio {r.priority}) was shed"
            if e.reason == "deadline":
                assert pol.shed_ms is not None
                assert e.wait_ms > pol.shed_ms
            else:
                assert e.reason == "admission"
                assert pol.max_queue is not None
        else:
            # -- invariant 5: results route to the right future -----------
            assert value == ("ok", r.uid)

    # -- invariant 3: no request both shed and served ----------------------
    assert not set(served_uids) & set(shed_uids)
    assert sorted(served_uids + shed_uids) == [r.uid for r in requests]
    assert len(served_uids) == len(set(served_uids)), "request served twice"
    assert sched.coalesced == len(served_uids)
    assert sched.shed_total == len(shed_uids)
    assert sched.flushes == len(calls)

    # -- invariant 2: per-lane service order == submission order -----------
    for lane in LANES:
        lane_order = [r.uid for b in calls for r in b if r.lane == lane]
        assert lane_order == sorted(lane_order), \
            f"lane {lane!r} served out of submission order: {lane_order}"
