"""Table 4: choice of positive actions for the pretraining losses.
Paper: Save / +Download / +Clickthrough / All-Hide / All-Hide-Clickthrough.
Our synthetic actions: save=1, download=2, clickthrough=3, click=4, hide=5."""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import (csv_row, data_cfg, default_fcfg,
                               finetune_and_eval, lift, pinfm_cfg, pretrain)
from repro.data.synthetic import SyntheticActivity

SETTINGS = [
    ("save", (1,)),
    ("save+download", (1, 2)),
    ("save+clickthrough", (1, 3)),
    ("all-hide", (1, 2, 3, 4)),
    ("all-hide-clickthrough", (1, 2, 4)),
]


def main():
    data = SyntheticActivity(data_cfg())
    results = {}
    for name, actions in SETTINGS:
        t0 = time.perf_counter()
        pcfg = pinfm_cfg().replace(pos_actions=actions)
        _, pre, _ = pretrain(pcfg, data=data)
        m, _ = finetune_and_eval(pcfg, default_fcfg(), pre, data=data)
        results[name] = m
        csv_row(f"table4/{name}", (time.perf_counter() - t0) * 1e6,
                f"save_hit3={m['save_overall']:.4f};"
                f"hide_hit3={m['hide_overall']:.4f}")
    base = results["save"]
    for name, _ in SETTINGS[1:]:
        csv_row(f"table4/lift[{name}]", 0,
                f"save={lift(results[name]['save_overall'], base['save_overall']):+.2f}%;"
                f"hide={lift(results[name]['hide_overall'], base['hide_overall']):+.2f}%")


if __name__ == "__main__":
    main()
