"""Table 1: input-sequence construction variants during fine-tuning.
Paper (Save HIT@3 lift vs w/o PinFM, HF): base +2.91, graphsage +3.08,
graphsage-lt +3.76, lite-mean +1.87, lite-last +1.93 — ordering:
early fusion > late fusion, GS-LT best."""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import (baseline_eval, csv_row, data_cfg, default_fcfg,
                               finetune_and_eval, lift, pinfm_cfg, pretrain)
from repro.data.synthetic import SyntheticActivity

VARIANTS = ["base", "graphsage", "graphsage-lt", "lite-mean", "lite-last"]


def main():
    data = SyntheticActivity(data_cfg())
    pcfg = pinfm_cfg()
    t0 = time.perf_counter()
    _, pre_params, _ = pretrain(pcfg, data=data)
    csv_row("table1/pretrain", (time.perf_counter() - t0) * 1e6, "")

    base = baseline_eval(data=data)
    csv_row("table1/wo_pinfm", 0,
            f"save_hit3={base['save_overall']:.4f}")
    for variant in VARIANTS:
        t0 = time.perf_counter()
        fcfg = default_fcfg(variant=variant)
        m, _ = finetune_and_eval(pcfg, fcfg, pre_params, data=data)
        csv_row(f"table1/{variant}", (time.perf_counter() - t0) * 1e6,
                f"save_hit3={m['save_overall']:.4f};"
                f"lift={lift(m['save_overall'], base['save_overall']):+.1f}%")


if __name__ == "__main__":
    main()
