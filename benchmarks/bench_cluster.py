"""Cluster serving benchmark: aggregate throughput scaling from cache
affinity, plus the kill-one-worker drain latency.

The cluster tier's win on an affinity-friendly mix is NOT parallel
compute (in-process workers share one device) — it is cache residency:
rendezvous routing keeps each repeat user on the worker whose
ContextCache already holds their encoded sequence.  The workload makes
that mechanism the bottleneck, the way a production user population
does to a single host:

  * per-engine ContextCache capacity C, repeat-user population 1.5C,
    cycled sequentially — the LRU's worst case: ANY population over
    capacity makes a sequential cycle evict every user before their next
    request returns, so ONE engine's steady-state hit rate is exactly 0
    and every request pays the full context-transformer encode;
  * TWO workers each own ~0.75C users by rendezvous hashing, so both
    caches fit their population with headroom — after the first pass the
    stream is ~all hits and the encode disappears from the steady state.

The context length is the serving bench's L=256 (paper §4.1): at toy L
the context transformer is too cheap for cache residency to matter.

Sections:

  1. scaling — the same R-pass stream through a single engine and
     through a 2-worker cluster (in-process ``EngineWorker``s, identical
     engine construction), timing the steady-state passes (pass 1, which
     populates the caches, is excluded on both sides).  Reports
     aggregate items/sec and per-side cache hit rates, and asserts
     cluster results == single engine bit-for-bit on the full stream.
  2. drain — a batch in flight, one worker killed: time from ``kill``
     until every future has resolved (re-routed to the survivor), with
     the results still bit-identical.

Emits BENCH_cluster.json.  --smoke shrinks the stream and asserts the
CORRECTNESS half only (bitwise parity, zero post-warmup compiles,
futures never hang); the full run additionally asserts the >= 1.6x
2-worker aggregate items/sec acceptance bar.

Run:   PYTHONPATH=src python benchmarks/bench_cluster.py [--smoke]
"""
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(os.path.dirname(__file__)))

import numpy as np
import jax

from repro.cluster import ClusterRouter, EngineWorker, WorkerCore
from repro.configs import smoke_config
from repro.core.dcat import DCAT
from repro.core.finetune import FinetuneConfig, PinFMRankingModel
from repro.core.losses import LossConfig
from repro.core.pretrain import PinFMConfig, PinFMPretrain
from repro.models.config import get_config
from repro.serving import ContextCache, RankRequest, ServingEngine

SMOKE = "--smoke" in sys.argv
L = 64 if SMOKE else 256               # context length: encode must matter
CACHE_CAP = 16 if SMOKE else 48        # C: per-engine ContextCache slots
N_USERS = 3 * CACHE_CAP // 2           # 1.5C: thrashes one cache, fits two
PASSES = 3 if SMOKE else 5             # pass 1 warms caches, untimed
N_CAND = 3
SPEEDUP_BAR = 1.6

JSON_PATH = os.path.join(os.path.dirname(__file__), "..",
                         "BENCH_cluster.json")


def build():
    """Bench-scale lite-last ranking model at context length L — late
    fusion, so a ContextCache hit skips the context transformer."""
    bb = smoke_config(get_config("pinfm-20b")).replace(
        n_layers=2, d_model=64, d_ff=128, n_heads=4, n_kv=4, head_dim=16)
    pcfg = PinFMConfig(rows=4096, n_tables=4, sub_dim=16, seq_len=L,
                       loss=LossConfig(window=4, downstream_len=16,
                                       n_negatives=0))
    fcfg = FinetuneConfig(variant="lite-last", seq_len=L, user_feat_dim=8,
                          cand_feat_dim=8, hidden=64, n_cross_layers=2,
                          seq_loss=LossConfig(use_mtl=False, use_ftl=False,
                                              n_negatives=0))
    model = PinFMRankingModel.__new__(PinFMRankingModel)
    model.__init__(pcfg, fcfg)
    model.pinfm = PinFMPretrain(pcfg, bb)
    model.dcat = DCAT(model.pinfm.body, fcfg.dcat)
    params = model.init(jax.random.PRNGKey(0))
    return model, params, fcfg


def mk_engine(model, params):
    return ServingEngine(model, params, max_unique=4,
                         max_candidates=4 * N_CAND,
                         cache=ContextCache(capacity=CACHE_CAP))


def mk_requests(fcfg):
    def req(seed):
        r = np.random.RandomState(seed)
        ids = r.randint(0, 4096, N_CAND)
        return RankRequest(
            seq_ids=r.randint(0, 4096, L),
            seq_actions=r.randint(0, 6, L),
            seq_surfaces=r.randint(0, 3, L),
            cand_ids=ids,
            cand_feats=r.randn(N_CAND, fcfg.cand_feat_dim)
            .astype(np.float32),
            user_feats=r.randn(fcfg.user_feat_dim).astype(np.float32))

    return [req(s) for s in range(N_USERS)]


def run_stream(submit_many, flush, reqs, cache_counts):
    """R passes over the repeat-user population; returns (results of the
    last pass, steady-state items/sec over passes 2..R, steady-state
    cache hit rate).  ``cache_counts()`` -> summed (hits, misses)."""
    futs = submit_many(reqs)        # pass 1: populates caches, untimed
    flush()
    [f.result() for f in futs]
    h0, m0 = cache_counts()
    t0 = time.perf_counter()
    for _ in range(PASSES - 1):
        futs = submit_many(reqs)
        flush()
        out = [f.result() for f in futs]
    dt = time.perf_counter() - t0
    h1, m1 = cache_counts()
    n = (h1 - h0) + (m1 - m0)
    return (out, (PASSES - 1) * len(reqs) * N_CAND / dt,
            (h1 - h0) / n if n else 0.0)


def main():
    model, params, fcfg = build()
    reqs = mk_requests(fcfg)

    # -- section 1: single engine vs 2-worker cluster -----------------------
    single = mk_engine(model, params)
    single.warmup()

    def single_counts():
        c = single.stats()["cache"]
        return c["hits"], c["misses"]

    ref, single_ips, single_hits = run_stream(
        single.submit_many, single.flush, reqs, single_counts)
    assert single.registry.compiles_after_warmup == 0

    workers = {f"w{i}": EngineWorker(
        f"w{i}", WorkerCore(mk_engine(model, params))) for i in range(2)}
    router = ClusterRouter(workers, fanout_unique=4)
    router.warmup()

    def cluster_counts():
        per = router.stats()["per_worker"]
        return (sum(s["engine"]["cache"]["hits"] for s in per.values()),
                sum(s["engine"]["cache"]["misses"] for s in per.values()))

    got, cluster_ips, cluster_hits = run_stream(
        router.submit_many, router.flush, reqs, cluster_counts)
    for a, b in zip(got, ref):
        np.testing.assert_array_equal(a, b)
    for name, w in workers.items():
        assert w.call("compiles_after_warmup") == 0, name
    speedup = cluster_ips / single_ips
    print(f"scaling ({N_USERS} repeat users @ L={L}, cache capacity "
          f"{CACHE_CAP} per engine, {PASSES - 1} steady-state passes):")
    print(f"  1 engine : {single_ips:8.1f} items/s  "
          f"(cache hit rate {single_hits * 100:5.1f}%)")
    print(f"  2 workers: {cluster_ips:8.1f} items/s  "
          f"(cache hit rate {cluster_hits * 100:5.1f}%)  "
          f"-> {speedup:.2f}x aggregate")
    print("  parity: cluster stream == single engine bit-for-bit, "
          "0 post-warmup compiles everywhere")

    # -- section 2: kill-one-worker drain latency ---------------------------
    futs = router.submit_many(reqs)
    victim = router.owner_of(reqs[0])
    t0 = time.perf_counter()
    router.kill_worker(victim)
    out = [f.result(timeout=120.0) for f in futs]       # never hangs
    drain_ms = (time.perf_counter() - t0) * 1e3
    for a, b in zip(out, ref):
        np.testing.assert_array_equal(a, b)
    st = router.stats()
    assert st["n_alive"] == 1 and st["deaths"] == 1
    print(f"drain: killed {victim} with {len(futs)} in flight — all "
          f"resolved bit-identically in {drain_ms:.0f} ms "
          f"(reroutes={st['reroutes']})")
    router.close()
    single.close()

    rows = [{"workers": 1, "items_per_s": single_ips,
             "cache_hit_rate": single_hits},
            {"workers": 2, "items_per_s": cluster_ips,
             "cache_hit_rate": cluster_hits}]
    with open(JSON_PATH, "w") as f:
        json.dump({"mode": "smoke" if SMOKE else "full", "seq_len": L,
                   "cache_capacity": CACHE_CAP, "n_users": N_USERS,
                   "passes_timed": PASSES - 1, "rows": rows,
                   "speedup": speedup, "speedup_bar": SPEEDUP_BAR,
                   "drain_ms": drain_ms}, f, indent=2)
    print(f"wrote {os.path.normpath(JSON_PATH)}")

    if not SMOKE:
        assert speedup >= SPEEDUP_BAR, (
            f"2-worker aggregate {speedup:.2f}x < {SPEEDUP_BAR}x bar")
        print(f"acceptance: {speedup:.2f}x >= {SPEEDUP_BAR}x")
    print("OK")


if __name__ == "__main__":
    main()
