"""Roofline analysis (deliverable g): three terms per (arch x shape x mesh)
from the dry-run artifacts in experiments/dryrun/*.json.

  compute    = HLO_FLOPs_per_chip / peak_FLOP/s          (197 TF bf16, v5e)
  memory     = HLO_bytes_per_chip / HBM_bw               (819 GB/s)
  collective = collective_bytes_per_chip / ICI_link_bw   (50 GB/s)

HLO_FLOPs / bytes / collective bytes come from the trip-count-aware HLO
analyzer (repro.launch.hlo_analysis) — XLA's cost_analysis counts scan
bodies once, which would undercount every term here (all layers/microbatch/
attention-block loops are scans).  MODEL_FLOPS is the analytic useful-work
estimate (6*N*D train / 2*N*D prefill / 2*N*D_token decode; N = active
non-embedding params), so MODEL/HLO exposes remat + dispatch overheads.

Usage:  python -m benchmarks.roofline [--dir experiments/dryrun] [--md out.md]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

PEAK = 197e12        # bf16 FLOP/s per v5e chip
HBM = 819e9          # bytes/s
ICI = 50e9           # bytes/s per link


def active_params(cfg) -> float:
    """Non-embedding params; for MoE, only routed-active experts count."""
    d, L = cfg.d_model, cfg.n_layers
    hd = cfg.resolved_head_dim
    attn = d * hd * (cfg.n_heads * 2 + cfg.n_kv * 2)
    kinds = cfg.block_kinds()
    total = 0.0
    for k in kinds:
        if k == "attn":
            ffn = 3 * d * cfg.d_ff if cfg.mlp_type == "glu" else 2 * d * cfg.d_ff
            total += attn + ffn
        elif k == "moe":
            e_ff = cfg.moe_d_ff or cfg.d_ff
            routed = 3 * d * e_ff * cfg.top_k
            shared = 3 * d * (cfg.shared_d_ff or e_ff) * cfg.n_shared
            total += attn + routed + shared
        elif k == "rec":
            w = cfg.lru_width or d
            total += 3 * d * w + 2 * w * w + (3 * d * cfg.d_ff)
        elif k == "ssm":
            di = cfg.ssm_expand * d
            total += 2 * d * di + di * d + 2 * d * cfg.ssm_state \
                + d * (di // cfg.ssm_head_dim)
    if cfg.family == "audio":
        total += cfg.encoder_layers * (attn + 2 * d * cfg.d_ff) \
            + L * (attn + 2 * d * cfg.d_ff)   # decoder cross-attn approx
    return total


def model_flops(cfg, shape) -> float:
    """Whole-step useful FLOPs (all chips)."""
    N = active_params(cfg)
    if shape.kind == "train":
        tokens = shape.batch * shape.seq
        return 6.0 * N * tokens
    if shape.kind == "prefill":
        return 2.0 * N * shape.batch * shape.seq
    if shape.kind == "decode":
        return 2.0 * N * shape.batch          # one token per sequence
    if shape.kind == "pretrain":
        return 6.0 * N * shape.batch * shape.seq
    if shape.kind == "rank_serve":
        # context once per unique user + crossing per candidate
        uniq = max(shape.batch // 128, 16)
        return 2.0 * N * (uniq * shape.seq + shape.batch * 2)
    return 0.0


def load_records(dirname):
    recs = []
    for f in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def roofline_row(rec) -> dict | None:
    if rec["status"] != "ok":
        return None
    from repro.launch.shapes import SHAPES
    from repro.models.config import get_config
    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    ana = rec.get("hlo_analysis")
    if not ana:
        return None
    n_dev = rec["n_devices"]
    t_comp = ana["flops"] / PEAK
    t_mem = ana["hbm_bytes"] / HBM
    t_coll = ana["collectives"]["total_bytes"] / ICI
    dominant = max(("compute", t_comp), ("memory", t_mem),
                   ("collective", t_coll), key=lambda kv: kv[1])[0]
    mf = model_flops(cfg, shape) / n_dev
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops_per_chip": mf,
        "hlo_flops_per_chip": ana["flops"],
        "useful_ratio": mf / ana["flops"] if ana["flops"] else 0.0,
        "temp_gib": rec["memory"]["temp_size_in_bytes"] / 2 ** 30,
        "args_gib": rec["memory"]["argument_size_in_bytes"] / 2 ** 30,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="pod_16x16")
    ap.add_argument("--md", default=None, help="write a markdown table here")
    ap.add_argument("--csv", action="store_true")
    args = ap.parse_args()

    rows = []
    for rec in load_records(args.dir):
        if rec.get("mesh") != args.mesh:
            continue
        r = roofline_row(rec)
        if r:
            rows.append(r)
    rows.sort(key=lambda r: (r["arch"], r["shape"]))

    hdr = (f"{'arch':22s} {'shape':14s} {'comp(s)':>9s} {'mem(s)':>9s} "
           f"{'coll(s)':>9s} {'dominant':>10s} {'useful':>7s} {'temp':>8s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r['arch']:22s} {r['shape']:14s} {r['t_compute_s']:9.4f} "
            f"{r['t_memory_s']:9.4f} {r['t_collective_s']:9.4f} "
            f"{r['dominant']:>10s} {r['useful_ratio']:7.2f} "
            f"{r['temp_gib']:7.1f}G")
    print("\n".join(lines))

    if args.md:
        with open(args.md, "w") as f:
            f.write("| arch | shape | compute (s) | memory (s) | "
                    "collective (s) | dominant | useful FLOP ratio | "
                    "temp GiB |\n|---|---|---|---|---|---|---|---|\n")
            for r in rows:
                f.write(f"| {r['arch']} | {r['shape']} | "
                        f"{r['t_compute_s']:.4f} | {r['t_memory_s']:.4f} | "
                        f"{r['t_collective_s']:.4f} | {r['dominant']} | "
                        f"{r['useful_ratio']:.2f} | {r['temp_gib']:.1f} |\n")
    return rows


if __name__ == "__main__":
    main()
