"""Table 5: importance of fine-tuning.  Paper: frozen PinFM gives ~no Save
lift (+0.10%); fine-tuned gives +3.76%."""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import (baseline_eval, csv_row, data_cfg, default_fcfg,
                               finetune_and_eval, lift, pinfm_cfg, pretrain)
from repro.data.synthetic import SyntheticActivity


def main():
    data = SyntheticActivity(data_cfg())
    pcfg = pinfm_cfg()
    _, pre, _ = pretrain(pcfg, data=data)
    base = baseline_eval(data=data)
    csv_row("table5/wo_pinfm", 0, f"save_hit3={base['save_overall']:.4f}")
    for name, freeze in (("frozen_pinfm", True), ("finetuned_pinfm", False)):
        t0 = time.perf_counter()
        m, _ = finetune_and_eval(pcfg, default_fcfg(), pre, data=data,
                                 freeze_pinfm=freeze)
        csv_row(f"table5/{name}", (time.perf_counter() - t0) * 1e6,
                f"save_hit3={m['save_overall']:.4f};"
                f"lift={lift(m['save_overall'], base['save_overall']):+.1f}%")


if __name__ == "__main__":
    main()
