"""Benchmark driver (deliverable d): one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows.

  bench_dcat             §4.1  DCAT vs full self-attention throughput
  bench_quant            §4.2  int8/int4 PTQ error + fused dequant kernel
  bench_table1_fusion    Tbl 1 input-sequence variants (early vs late fusion)
  bench_table2_coldstart Tbl 2 CIR / IDD / GSLT cold-start techniques
  bench_table3_losses    Tbl 3 L_ntl / L_mtl / L_ftl ablations
  bench_table4_actions   Tbl 4 positive-action-set ablation
  bench_table5_finetuning Tbl 5 frozen vs fine-tuned PinFM
  bench_table6_vocab     Tbl 6 vocabulary-size scaling
  roofline               §Dry-run/§Roofline report (reads experiments/dryrun)

Set BENCH_QUICK=1 for a fast smoke pass; --only <name> to run a subset.
"""
import argparse
import os
import sys
import time
import traceback

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks import (bench_dcat, bench_fig3_iterations, bench_quant,
                        bench_retrieval, bench_table1_fusion,
                        bench_table2_coldstart, bench_table3_losses,
                        bench_table4_actions, bench_table5_finetuning,
                        bench_table6_vocab)

BENCHES = [
    ("dcat", bench_dcat.main),
    ("quant", bench_quant.main),
    ("retrieval", bench_retrieval.main),
    ("table1", bench_table1_fusion.main),
    ("table2", bench_table2_coldstart.main),
    ("table3", bench_table3_losses.main),
    ("table4", bench_table4_actions.main),
    ("table5", bench_table5_finetuning.main),
    ("table6", bench_table6_vocab.main),
    ("fig3", bench_fig3_iterations.main),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of benches")
    args, _ = ap.parse_known_args()
    only = set(args.only.split(",")) if args.only else None
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in BENCHES:
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            fn()
            print(f"bench/{name}/total,{(time.time() - t0) * 1e6:.0f},ok")
        except Exception:  # noqa: BLE001
            failures += 1
            traceback.print_exc()
            print(f"bench/{name}/total,0,FAILED")
    # roofline table (only if dry-run artifacts exist)
    if os.path.isdir("experiments/dryrun") and (not only or "roofline" in only):
        from benchmarks import roofline
        sys.argv = ["roofline"]
        roofline.main()
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
