"""Table 2: cold-start techniques.  Paper (HF Save HIT@3 lift, 28d-fresh):
cs-none -4.4%, +CIR +1.25%, +CIR+IDD +10.7%, +CIR+IDD+GSLT +17.7% — the
techniques flip the fresh-item regression into a gain."""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import (baseline_eval, csv_row, data_cfg, default_fcfg,
                               finetune_and_eval, lift, pinfm_cfg, pretrain)
from repro.data.synthetic import SyntheticActivity

SETTINGS = [
    ("cs-none", dict(variant="base", use_cir=False, use_idd=False)),
    ("cs-CIR", dict(variant="base", use_cir=True, use_idd=False)),
    ("cs-CIR-IDD", dict(variant="base", use_cir=True, use_idd=True)),
    ("cs-CIR-IDD-GSLT", dict(variant="graphsage-lt", use_cir=True,
                             use_idd=True)),
]


def main():
    data = SyntheticActivity(data_cfg())
    pcfg = pinfm_cfg()
    _, pre_params, _ = pretrain(pcfg, data=data)
    base = baseline_eval(data=data)
    csv_row("table2/wo_pinfm", 0,
            f"save_fresh={base['save_fresh']:.4f};"
            f"save_overall={base['save_overall']:.4f}")
    for name, kw in SETTINGS:
        t0 = time.perf_counter()
        m, _ = finetune_and_eval(pcfg, default_fcfg(**kw), pre_params,
                                 data=data)
        csv_row(f"table2/{name}", (time.perf_counter() - t0) * 1e6,
                f"save_fresh={m['save_fresh']:.4f};"
                f"fresh_lift={lift(m['save_fresh'], base['save_fresh']):+.1f}%;"
                f"overall_lift={lift(m['save_overall'], base['save_overall']):+.1f}%")


if __name__ == "__main__":
    main()
