"""Shared harness for the paper-table benchmarks: tiny-scale pretrain +
fine-tune + HIT@3 evaluation on the synthetic latent-interest stream.

Scale note: the paper's tables come from production-scale runs; here every
table is reproduced DIRECTIONALLY at laptop scale (2-layer backbone, 32-seq,
~10^2 steps).  Numbers are lifts vs the in-benchmark baseline, like the
paper reports."""
from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.core.dcat import DCATOptions
from repro.core.finetune import FinetuneConfig, PinFMRankingModel
from repro.core.losses import LossConfig
from repro.core.metrics import hit_at_k
from repro.core.pretrain import PinFMConfig, PinFMPretrain
from repro.data.synthetic import DataConfig, SyntheticActivity
from repro.models.config import get_config
from repro.nn.layers import _ACT, Linear
from repro.nn.module import Module
from repro.training.optim import AdamWConfig, adamw_init
from repro.training.train import make_train_step, train_loop

QUICK = os.environ.get("BENCH_QUICK", "0") == "1"

SEQ = 32
PRETRAIN_STEPS = 30 if QUICK else 150
FINETUNE_STEPS = 40 if QUICK else 400
EVAL_BATCHES = 4 if QUICK else 40


def data_cfg(seed=0):
    return DataConfig(n_users=400, n_items=1500, n_topics=16, seq_len=SEQ,
                      seed=seed)


def tiny_backbone():
    return smoke_config(get_config("pinfm-20b")).replace(
        n_layers=2, d_model=64, d_ff=128, n_heads=4, n_kv=4, head_dim=16)


def pinfm_cfg(**loss_kw):
    base = dict(window=4, downstream_len=16, n_negatives=0, mtl_stride=1)
    base.update(loss_kw)
    return PinFMConfig(rows=4096, n_tables=4, sub_dim=16, seq_len=SEQ,
                       loss=LossConfig(**base), pos_actions=(1, 2, 3))


def small_ranking_model(pcfg, fcfg):
    model = PinFMRankingModel.__new__(PinFMRankingModel)
    model.__init__(pcfg, fcfg)
    from repro.core.dcat import DCAT
    model.pinfm = PinFMPretrain(pcfg, tiny_backbone())
    model.dcat = DCAT(model.pinfm.body, fcfg.dcat)
    return model


def default_fcfg(**kw):
    base = dict(variant="graphsage-lt", seq_len=SEQ, graphsage_dim=16,
                user_feat_dim=8, cand_feat_dim=8, hidden=64,
                n_cross_layers=2,
                seq_loss=LossConfig(use_mtl=False, use_ftl=False,
                                    n_negatives=0, window=4,
                                    downstream_len=16))
    base.update(kw)
    return FinetuneConfig(**base)


def pretrain(pcfg, *, steps=PRETRAIN_STEPS, seed=0, data=None):
    data = data or SyntheticActivity(data_cfg())
    model = PinFMPretrain(pcfg, tiny_backbone())
    params = model.init(jax.random.PRNGKey(seed))
    opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=steps,
                          weight_decay=0.01)
    step = jax.jit(make_train_step(model.loss, opt_cfg))
    opt = adamw_init(params)
    params, _, hist = train_loop(step, params, opt,
                                 data.pretrain_batches(16, steps, seed + 1),
                                 log_every=0)
    return model, params, hist


def finetune_and_eval(pcfg, fcfg, pretrained=None, *, steps=FINETUNE_STEPS,
                      seed=0, data=None, freeze_pinfm=False):
    """Returns dict of HIT@3 metrics (save/hide overall + fresh)."""
    data = data or SyntheticActivity(data_cfg())
    model = small_ranking_model(pcfg, fcfg)
    params = model.init(jax.random.PRNGKey(seed + 100))
    if pretrained is not None:
        params = dict(params)
        params["pinfm"] = pretrained
    lr_mults = {"pinfm": 0.0 if freeze_pinfm else 0.1}
    opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=steps,
                          weight_decay=0.01, lr_mults=lr_mults)

    def loss_fn(p, batch, rng):
        return model.loss(p, batch, rng=rng, train=True)

    step = jax.jit(make_train_step(loss_fn, opt_cfg, has_rng=True))
    opt = adamw_init(params)
    params, _, _ = train_loop(
        step, params, opt,
        data.ranking_batches(4, 8, steps, seed=seed + 2), log_every=0,
        rng=jax.random.PRNGKey(seed + 3))
    return evaluate(model, params, data, seed=seed), params


def evaluate(model, params, data, *, seed=0):
    fwd = jax.jit(lambda p, b: model.forward(p, b, train=False)[0])
    out = {}
    for name, fresh_p in (("overall", 0.25), ("fresh", 1.0)):
        hits_save, hits_hide = [], []
        for i, b in enumerate(data.ranking_batches(
                8, 8, EVAL_BATCHES, seed=seed + 900 + int(fresh_p * 10),
                fresh_prob=fresh_p)):
            logits = np.asarray(fwd(params, jax.tree.map(jnp.asarray, b)))
            scores = logits[:, 0].reshape(8, 8)        # save head
            save = b["labels"][:, 0].reshape(8, 8)
            hide = b["labels"][:, 2].reshape(8, 8)
            hits_save.append(float(hit_at_k(jnp.asarray(scores),
                                            jnp.asarray(save))))
            hits_hide.append(float(hit_at_k(jnp.asarray(scores),
                                            jnp.asarray(hide))))
        out[f"save_{name}"] = float(np.mean(hits_save))
        out[f"hide_{name}"] = float(np.mean(hits_hide))
    return out


# -- no-PinFM baseline ranker --------------------------------------------------

class NoPinFMRanker(Module):
    """The downstream ranking model WITHOUT the PinFM module (w/o PinFM rows
    of Tables 1/2): user+candidate dense features through the same DCN."""

    def __init__(self, fcfg: FinetuneConfig):
        from repro.core.finetune import CrossNetwork
        self.cfg = fcfg
        in_dim = fcfg.user_feat_dim + fcfg.cand_feat_dim + fcfg.graphsage_dim
        self.in_proj = Linear(in_dim, fcfg.hidden, axes=(None, "embed"),
                              bias=True)
        self.cross = CrossNetwork(fcfg.hidden, fcfg.n_cross_layers)
        self.mid = Linear(fcfg.hidden, fcfg.hidden, axes=("embed", "mlp"),
                          bias=True)
        self.heads = Linear(fcfg.hidden, fcfg.n_tasks, axes=("mlp", None),
                            bias=True)

    def spec(self):
        return {"in_proj": self.in_proj.spec(), "cross": self.cross.spec(),
                "mid": self.mid.spec(), "heads": self.heads.spec()}

    def forward(self, p, batch, train=False, rng=None):
        user_f = jnp.take(batch["user_feats"], batch["inverse_idx"], axis=0)
        x = jnp.concatenate([user_f, batch["cand_feats"],
                             batch["graphsage"]], -1)
        x = self.in_proj(p["in_proj"], x)
        x = self.cross(p["cross"], x)
        x = _ACT["relu"](self.mid(p["mid"], x))
        return self.heads(p["heads"], x), None, None

    def loss(self, p, batch, rng=None, train=True):
        logits, _, _ = self.forward(p, batch)
        labels = batch["labels"].astype(jnp.float32)
        lg = logits.astype(jnp.float32)
        bce = jnp.mean(jnp.maximum(lg, 0) - lg * labels
                       + jnp.log1p(jnp.exp(-jnp.abs(lg))))
        return bce, ({"bce": bce}, logits)


def baseline_eval(*, seed=0, data=None):
    data = data or SyntheticActivity(data_cfg())
    fcfg = default_fcfg()
    model = NoPinFMRanker(fcfg)
    params = model.init(jax.random.PRNGKey(seed))
    opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=10,
                          total_steps=FINETUNE_STEPS, weight_decay=0.01)

    def loss_fn(p, batch, rng):
        return model.loss(p, batch, rng=rng)

    step = jax.jit(make_train_step(loss_fn, opt_cfg, has_rng=True))
    opt = adamw_init(params)
    params, _, _ = train_loop(
        step, params, opt, data.ranking_batches(4, 8, FINETUNE_STEPS,
                                                seed=seed + 2),
        log_every=0, rng=jax.random.PRNGKey(seed + 3))
    return evaluate(model, params, data, seed=seed)


def lift(x, base):
    return 100.0 * (x - base) / max(abs(base), 1e-9)


def csv_row(name, us, derived=""):
    print(f"{name},{us:.1f},{derived}")
