"""Table 3: pretraining/fine-tuning loss ablations.  Paper: adding L_mtl then
L_ftl to pretraining improves Save (+0.42, +0.95); fine-tuning without the
sequence loss drops Save; ntl in fine-tuning is the default."""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import (csv_row, data_cfg, default_fcfg,
                               finetune_and_eval, lift, pinfm_cfg, pretrain)
from repro.data.synthetic import SyntheticActivity

PRETRAIN_SETTINGS = [
    ("ntl", dict(use_mtl=False, use_ftl=False)),
    ("ntl+mtl", dict(use_mtl=True, use_ftl=False)),
    ("ntl+mtl+ftl", dict(use_mtl=True, use_ftl=True)),
]


def main():
    data = SyntheticActivity(data_cfg())
    results = {}
    for name, kw in PRETRAIN_SETTINGS:
        t0 = time.perf_counter()
        pcfg = pinfm_cfg(**kw)
        _, pre, _ = pretrain(pcfg, data=data)
        m, _ = finetune_and_eval(pcfg, default_fcfg(), pre, data=data)
        results[name] = m
        csv_row(f"table3/pre[{name}]+ft[ntl]",
                (time.perf_counter() - t0) * 1e6,
                f"save_hit3={m['save_overall']:.4f};"
                f"hide_hit3={m['hide_overall']:.4f}")
    base = results["ntl"]
    for name in ("ntl+mtl", "ntl+mtl+ftl"):
        csv_row(f"table3/lift[{name}]", 0,
                f"save={lift(results[name]['save_overall'], base['save_overall']):+.2f}%;"
                f"hide={lift(results[name]['hide_overall'], base['hide_overall']):+.2f}%")
    # fine-tuning without the sequence loss
    pcfg = pinfm_cfg(use_mtl=True, use_ftl=True)
    _, pre, _ = pretrain(pcfg, data=data)
    t0 = time.perf_counter()
    m_none, _ = finetune_and_eval(pcfg, default_fcfg(use_seq_loss=False),
                                  pre, data=data)
    csv_row("table3/pre[all]+ft[none]", (time.perf_counter() - t0) * 1e6,
            f"save_hit3={m_none['save_overall']:.4f};"
            f"vs_ft_ntl={lift(m_none['save_overall'], results['ntl+mtl+ftl']['save_overall']):+.2f}%")


if __name__ == "__main__":
    main()
