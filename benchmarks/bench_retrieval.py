"""Corpus retrieval benchmark: items/sec and p50 latency for exact top-k
over a packed item corpus, across corpus sizes and execution paths.

  fp32    — brute force: the corpus resident as a dequantized fp32 table,
            one giant ``lax.top_k(q @ T.T, k)``.  Reads 4 bytes/dim/item
            AND materializes the full (Q, R) score matrix every call.
  int4    — the fused streaming path (``CorpusScorer(mode="fused")``):
            packed int4 codes (0.5 bytes/dim/item), dequant + score +
            block-max top-k selection streamed chunk-by-chunk in cache.
  sharded — the same fused path split across all local devices via
            ``ShardedRetriever`` (1 device on CPU CI == fused + shard_map).
  pallas  — the fused TPU kernel, interpret mode (smallest corpus only;
            the interpreter is python-per-block and not a speed claim).

Acceptance target (largest corpus): int4 fused >= 2x fp32 items/sec.
Every path ranks the same dequantized scores; each run asserts the top-k
score vectors agree across paths (exact INDEX parity incl. ties is pinned
by the lattice-data tests in tests/test_retrieval.py — on continuous
random data, cross-path index equality at ulp-level near-ties is not a
meaningful benchmark invariant).

Run:  PYTHONPATH=src python benchmarks/bench_retrieval.py [--smoke]
      BENCH_QUICK=1 shrinks corpora for CI.
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(os.path.dirname(__file__)))

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import QUICK, csv_row
from repro.quant import quantize_table
from repro.retrieval import (CorpusScorer, ItemFilter, ItemIndex,
                             ShardedRetriever)

SMOKE = "--smoke" in sys.argv or QUICK
D = 64
K = 100 if not SMOKE else 32
Q = 128 if not SMOKE else 32
SIZES = (65_536, 262_144, 1_048_576) if not SMOKE else (16_384, 65_536)
REPS = 5 if not SMOKE else 3


def p50(fn, *args):
    out = fn(*args)
    jax.block_until_ready(out)
    ts = []
    for _ in range(REPS):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)), out


def main():
    rng = np.random.RandomState(0)
    results = {}
    for R in SIZES:
        table = (0.05 * rng.randn(R, D)).astype(np.float32)
        qt = quantize_table(jnp.asarray(table), 4)
        index = ItemIndex(qt=qt, start_id=0, n_items=R)
        # the fp32 brute-force corpus serves the SAME dequantized values,
        # so every path ranks identical scores (exactness check below)
        t_fp32 = index.dequantize()
        q = jnp.asarray((0.05 * rng.randn(Q, D)).astype(np.float32))

        brute = jax.jit(lambda q, t: jax.lax.top_k(q @ t.T, K))
        t_b, (bs, br) = p50(brute, q, t_fp32)
        csv_row(f"retrieval/fp32/R{R}", t_b * 1e6,
                f"items_per_s={R / t_b:.3e};Q={Q};k={K}")

        scorer = CorpusScorer(index, mode="fused", chunk_rows=32768,
                              block_rows=32)
        t_f, (fs, fr) = p50(scorer.topk, q, K)
        csv_row(f"retrieval/int4_fused/R{R}", t_f * 1e6,
                f"items_per_s={R / t_f:.3e};speedup_vs_fp32={t_b / t_f:.2f}x")
        assert np.allclose(np.asarray(fs), np.asarray(bs), atol=1e-5), \
            "fused scores diverged from brute force"

        # filtered query: every query excludes its own 1k "already-seen"
        # ids (the production seen-item filter) — same fused path, mask
        # packed on host per call, result provably mask-clean
        n_seen = min(1024, R // 4)
        filts = [ItemFilter(exclude_ids=rng.choice(R, n_seen, replace=False))
                 for _ in range(Q)]
        t_flt, (xs, xr) = p50(lambda: scorer.topk(q, K, filters=filts))
        csv_row(f"retrieval/int4_filtered/R{R}", t_flt * 1e6,
                f"items_per_s={R / t_flt:.3e};"
                f"overhead_vs_unfiltered={t_flt / t_f:.2f}x;seen={n_seen}")
        xr_np = np.asarray(xr)
        for qi in (0, Q - 1):
            assert not np.isin(
                xr_np[qi], np.asarray(filts[qi].exclude_ids)).any(), \
                "filtered retrieval returned an excluded item"
        # removing candidates can only lower the k-th best score
        assert (np.asarray(xs) <= np.asarray(fs) + 1e-5).all(), \
            "filtered scores exceed unfiltered top-k"

        sharded = ShardedRetriever(index, chunk_rows=32768, block_rows=32)
        t_s, (ss, sr) = p50(sharded.topk, q, K)
        csv_row(f"retrieval/sharded{sharded.n_shards}/R{R}", t_s * 1e6,
                f"items_per_s={R / t_s:.3e};speedup_vs_fp32={t_b / t_s:.2f}x")
        assert np.allclose(ss, np.asarray(fs), atol=1e-5), \
            "sharded top-k scores diverged from single-device fused"

        if R == SIZES[0]:
            pal = CorpusScorer(index, mode="pallas")
            t_p, (ps, pr) = p50(pal.topk, q, K)
            csv_row(f"retrieval/pallas_interpret/R{R}", t_p * 1e6,
                    f"items_per_s={R / t_p:.3e}")
            assert np.allclose(np.asarray(ps), np.asarray(fs), atol=1e-5), \
                "pallas kernel top-k scores diverged from fused"
        results[R] = (t_b, t_f)

    t_b, t_f = results[SIZES[-1]]
    csv_row(f"retrieval/acceptance/R{SIZES[-1]}", 0,
            f"int4_vs_fp32={t_b / t_f:.2f}x;target>=2x")
    if not SMOKE:
        assert t_b / t_f >= 2.0, (
            f"int4 fused path is only {t_b / t_f:.2f}x fp32 brute force at "
            f"R={SIZES[-1]} (acceptance target: >=2x items/sec)")


if __name__ == "__main__":
    main()
