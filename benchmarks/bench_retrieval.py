"""Corpus retrieval benchmark: items/sec and p50 latency for exact top-k
over a packed item corpus, across corpus sizes and execution paths.

  fp32    — brute force: the corpus resident as a dequantized fp32 table,
            one giant ``lax.top_k(q @ T.T, k)``.  Reads 4 bytes/dim/item
            AND materializes the full (Q, R) score matrix every call.
  int4    — the fused streaming path (``CorpusScorer(mode="fused")``):
            packed int4 codes (0.5 bytes/dim/item), dequant + score +
            block-max top-k selection streamed chunk-by-chunk in cache.
  sharded — the same fused path split across all local devices via
            ``ShardedRetriever`` (1 device on CPU CI == fused + shard_map).
  pallas  — the fused TPU kernel, interpret mode (smallest corpus only;
            the interpreter is python-per-block and not a speed claim).

Acceptance target (largest corpus): int4 fused >= 2x fp32 items/sec.
Every path ranks the same dequantized scores; each run asserts the top-k
score vectors agree across paths (exact INDEX parity incl. ties is pinned
by the lattice-data tests in tests/test_retrieval.py — on continuous
random data, cross-path index equality at ulp-level near-ties is not a
meaningful benchmark invariant).

Section 2 — IVF-ANN route (``--smoke`` shrinks corpora): the coarse-
quantized route over the SAME scorer machinery.  For each corpus size it
sweeps the ``nprobe`` ladder and records the recall@k vs items/sec
frontier — recall measured against the exact fused result, throughput as
nominal corpus items served per second — unfiltered and with the 1k
seen-item filter pushed into the probed slices.  Also times the two
top-k merges (bitonic network vs lexicographic sort / flat top_k) on
both the exact kernel and the IVF slice scan, asserting bit-identical
results.  Emits BENCH_ivf.json (smoke too — CI gates on it).

Acceptance (full runs only; smoke reports): at the largest corpus some
probe width reaches recall@k >= 0.95 while serving >= 3x the exact
path's items/sec, and the kernel's bitonic merge is >= 1.1x its
lax.sort merge.

Run:  PYTHONPATH=src python benchmarks/bench_retrieval.py [--smoke]
      BENCH_QUICK=1 shrinks corpora for CI.
"""
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(os.path.dirname(__file__)))

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import QUICK, csv_row
from repro.kernels.retrieval_topk import retrieval_topk
from repro.quant import quantize_table
from repro.retrieval import (CorpusScorer, IVFScorer, ItemFilter, ItemIndex,
                             ShardedRetriever, build_ivf)

SMOKE = "--smoke" in sys.argv or QUICK
D = 64
K = 100 if not SMOKE else 32
Q = 128 if not SMOKE else 32
SIZES = (65_536, 262_144, 1_048_576) if not SMOKE else (16_384, 65_536)
REPS = 5 if not SMOKE else 3

# IVF frontier: the 10M point is the paper-scale claim; 1M anchors it
IVF_SIZES = (1_048_576, 10_485_760) if not SMOKE else (65_536,)
IVF_NPROBE = (1, 2, 4, 8, 16, 32) if not SMOKE else (1, 2, 4, 8)
IVF_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_ivf.json")


def p50(fn, *args):
    out = fn(*args)
    jax.block_until_ready(out)
    ts = []
    for _ in range(REPS):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)), out


def main():
    rng = np.random.RandomState(0)
    results = {}
    for R in SIZES:
        table = (0.05 * rng.randn(R, D)).astype(np.float32)
        qt = quantize_table(jnp.asarray(table), 4)
        index = ItemIndex(qt=qt, start_id=0, n_items=R)
        # the fp32 brute-force corpus serves the SAME dequantized values,
        # so every path ranks identical scores (exactness check below)
        t_fp32 = index.dequantize()
        q = jnp.asarray((0.05 * rng.randn(Q, D)).astype(np.float32))

        brute = jax.jit(lambda q, t: jax.lax.top_k(q @ t.T, K))
        t_b, (bs, br) = p50(brute, q, t_fp32)
        csv_row(f"retrieval/fp32/R{R}", t_b * 1e6,
                f"items_per_s={R / t_b:.3e};Q={Q};k={K}")

        scorer = CorpusScorer(index, mode="fused", chunk_rows=32768,
                              block_rows=32)
        t_f, (fs, fr) = p50(scorer.topk, q, K)
        csv_row(f"retrieval/int4_fused/R{R}", t_f * 1e6,
                f"items_per_s={R / t_f:.3e};speedup_vs_fp32={t_b / t_f:.2f}x")
        assert np.allclose(np.asarray(fs), np.asarray(bs), atol=1e-5), \
            "fused scores diverged from brute force"

        # filtered query: every query excludes its own 1k "already-seen"
        # ids (the production seen-item filter) — same fused path, mask
        # packed on host per call, result provably mask-clean
        n_seen = min(1024, R // 4)
        filts = [ItemFilter(exclude_ids=rng.choice(R, n_seen, replace=False))
                 for _ in range(Q)]
        t_flt, (xs, xr) = p50(lambda: scorer.topk(q, K, filters=filts))
        csv_row(f"retrieval/int4_filtered/R{R}", t_flt * 1e6,
                f"items_per_s={R / t_flt:.3e};"
                f"overhead_vs_unfiltered={t_flt / t_f:.2f}x;seen={n_seen}")
        xr_np = np.asarray(xr)
        for qi in (0, Q - 1):
            assert not np.isin(
                xr_np[qi], np.asarray(filts[qi].exclude_ids)).any(), \
                "filtered retrieval returned an excluded item"
        # removing candidates can only lower the k-th best score
        assert (np.asarray(xs) <= np.asarray(fs) + 1e-5).all(), \
            "filtered scores exceed unfiltered top-k"

        sharded = ShardedRetriever(index, chunk_rows=32768, block_rows=32)
        t_s, (ss, sr) = p50(sharded.topk, q, K)
        csv_row(f"retrieval/sharded{sharded.n_shards}/R{R}", t_s * 1e6,
                f"items_per_s={R / t_s:.3e};speedup_vs_fp32={t_b / t_s:.2f}x")
        assert np.allclose(ss, np.asarray(fs), atol=1e-5), \
            "sharded top-k scores diverged from single-device fused"

        if R == SIZES[0]:
            pal = CorpusScorer(index, mode="pallas")
            t_p, (ps, pr) = p50(pal.topk, q, K)
            csv_row(f"retrieval/pallas_interpret/R{R}", t_p * 1e6,
                    f"items_per_s={R / t_p:.3e}")
            assert np.allclose(np.asarray(ps), np.asarray(fs), atol=1e-5), \
                "pallas kernel top-k scores diverged from fused"
        results[R] = (t_b, t_f)

    t_b, t_f = results[SIZES[-1]]
    csv_row(f"retrieval/acceptance/R{SIZES[-1]}", 0,
            f"int4_vs_fp32={t_b / t_f:.2f}x;target>=2x")
    if not SMOKE:
        assert t_b / t_f >= 2.0, (
            f"int4 fused path is only {t_b / t_f:.2f}x fp32 brute force at "
            f"R={SIZES[-1]} (acceptance target: >=2x items/sec)")


def _recall(ann_ids, exact_ids):
    """Mean fraction of the exact top-k each query's ANN result recovers."""
    return float(np.mean([
        len(set(a[a >= 0].tolist()) & set(e.tolist())) / len(e)
        for a, e in zip(ann_ids, exact_ids)]))


def section_ivf():
    """IVF-ANN frontier + merge-implementation timing -> BENCH_ivf.json."""
    rng = np.random.RandomState(1)
    report = {"smoke": SMOKE, "k": K, "q": Q, "d": D, "nprobe": [],
              "corpora": {}, "merge": {}, "acceptance": {}}
    best = None
    for R in IVF_SIZES:
        # mild cluster structure so the probe ladder sweeps a real
        # recall/throughput trade-off (iid gaussian rows make every
        # cluster equally attractive and flatten the frontier)
        C = int(max(64, min(8192, round(R ** 0.5))))
        centers = 0.05 * rng.randn(C, D).astype(np.float32)
        owner = rng.randint(0, C, R)
        table = (centers[owner]
                 + 0.02 * rng.randn(R, D)).astype(np.float32)
        index = ItemIndex(qt=quantize_table(jnp.asarray(table), 4),
                          start_id=0, n_items=R)
        del table
        q = (centers[rng.randint(0, C, Q)]
             + 0.02 * rng.randn(Q, D)).astype(np.float32)

        exact = CorpusScorer(index, mode="fused", chunk_rows=65536,
                             block_rows=32)
        t_e, (_, er) = p50(exact.topk, jnp.asarray(q), K)
        exact_ids = np.asarray(er)
        csv_row(f"retrieval/ivf_exact_base/R{R}", t_e * 1e6,
                f"items_per_s={R / t_e:.3e}")

        ividx = build_ivf(index, C, seed=0)
        exact_p = CorpusScorer(ividx, mode="fused", chunk_rows=65536,
                               block_rows=32)
        _, er_p = exact_p.retrieve(jnp.asarray(q), K)
        exact_ids = np.asarray(er_p)           # id space: permutation-proof
        filts = [ItemFilter(exclude_ids=rng.choice(R, 1024, replace=False))
                 for _ in range(Q)]
        _, ef = exact_p.retrieve(jnp.asarray(q), K, filters=filts)
        exact_f_ids = np.asarray(ef)

        entry = {"n_clusters": C, "exact_items_per_s": R / t_e,
                 "frontier": [], "filtered_frontier": []}
        for nprobe in IVF_NPROBE:
            if nprobe > C:
                break
            sc = IVFScorer(ividx, nprobe=nprobe, widen=0)
            t_i, (_, ir) = p50(sc.retrieve, q, K)
            rec = _recall(np.asarray(ir), exact_ids)
            speed = t_e / t_i
            S = sc.table.slots(nprobe)
            entry["frontier"].append(
                {"nprobe": nprobe, "recall": rec, "items_per_s": R / t_i,
                 "speedup_vs_exact": speed,
                 "rows_scanned_max": S * sc.slice_rows})
            csv_row(f"retrieval/ivf/R{R}/nprobe{nprobe}", t_i * 1e6,
                    f"recall@{K}={rec:.3f};items_per_s={R / t_i:.3e};"
                    f"speedup_vs_exact={speed:.2f}x")
            if rec >= 0.95 and R == IVF_SIZES[-1] and (
                    best is None or speed > best):
                best = speed
            t_if, (_, irf) = p50(lambda: sc.retrieve(q, K, filters=filts))
            rec_f = _recall(np.asarray(irf), exact_f_ids)
            entry["filtered_frontier"].append(
                {"nprobe": nprobe, "recall": rec_f,
                 "items_per_s": R / t_if,
                 "speedup_vs_exact_unfiltered": t_e / t_if})
            csv_row(f"retrieval/ivf_filtered/R{R}/nprobe{nprobe}",
                    t_if * 1e6, f"recall@{K}={rec_f:.3f};"
                    f"items_per_s={R / t_if:.3e}")
        report["corpora"][str(R)] = entry

        if R == IVF_SIZES[0]:
            # merge implementations, IVF path: streamed bitonic network
            # vs flat lax.top_k — bit-identical, speed reported
            sc_b = IVFScorer(ividx, nprobe=8, merge="bitonic")
            sc_t = IVFScorer(ividx, nprobe=8, merge="topk")
            t_mb, (sb, rb) = p50(sc_b.topk, q, K)
            t_mt, (st_, rt) = p50(sc_t.topk, q, K)
            assert np.array_equal(rb, rt) and np.array_equal(sb, st_), \
                "ivf merge modes diverged"
            report["merge"]["ivf_bitonic_vs_topk_speedup"] = t_mt / t_mb
            report["merge"]["ivf_bit_identical"] = True
            csv_row(f"retrieval/ivf_merge/R{R}", t_mb * 1e6,
                    f"bitonic_vs_topk={t_mt / t_mb:.2f}x;bit_identical=1")

    # merge implementations, exact kernel path: bitonic carry merge vs
    # the lexicographic lax.sort merge (interpret mode on CPU — the
    # >=1.1x acceptance is asserted on compiled (TPU) runs only)
    Rm = SIZES[0]
    rng2 = np.random.RandomState(2)
    qt_m = quantize_table(
        jnp.asarray((0.05 * rng2.randn(Rm, D)).astype(np.float32)), 4)
    q_m = jnp.asarray((0.05 * rng2.randn(Q, D)).astype(np.float32))
    t_kb, (kbs, kbr) = p50(lambda: retrieval_topk(
        qt_m.packed, qt_m.scale, qt_m.bias, q_m, k=K, block_rows=2048,
        merge="bitonic"))
    t_ks, (kss, ksr) = p50(lambda: retrieval_topk(
        qt_m.packed, qt_m.scale, qt_m.bias, q_m, k=K, block_rows=2048,
        merge="sort"))
    assert np.array_equal(np.asarray(kbr), np.asarray(ksr)) and \
        np.array_equal(np.asarray(kbs), np.asarray(kss)), \
        "kernel merge modes diverged"
    kernel_speed = t_ks / t_kb
    report["merge"]["kernel_bitonic_vs_sort_speedup"] = kernel_speed
    report["merge"]["kernel_bit_identical"] = True
    csv_row(f"retrieval/kernel_merge/R{Rm}", t_kb * 1e6,
            f"bitonic_vs_sort={kernel_speed:.2f}x;bit_identical=1;"
            f"target>=1.1x(full)")

    report["nprobe"] = list(IVF_NPROBE)
    report["acceptance"] = {
        "target_recall": 0.95, "target_speedup_vs_exact": 3.0,
        "best_speedup_at_recall_floor": best,
        "kernel_merge_target": 1.1,
        "kernel_merge_speedup": kernel_speed,
        "asserted": not SMOKE,
    }
    with open(IVF_JSON, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {os.path.normpath(IVF_JSON)}")
    if not SMOKE:
        assert best is not None and best >= 3.0, (
            f"IVF route reaches only {best}x exact items/sec at "
            f"recall@{K} >= 0.95 on R={IVF_SIZES[-1]} (target: >=3x)")
        assert kernel_speed >= 1.1, (
            f"bitonic kernel merge is only {kernel_speed:.2f}x the "
            f"lax.sort merge (target: >=1.1x)")


if __name__ == "__main__":
    main()
    section_ivf()
