"""Figure 3: downstream metrics vs number of pretraining iterations.
Paper: Save/Hide HIT@3 improve (non-monotonically) with more pretraining;
no one-epoch overfitting.  Here: 0 / 25% / 50% / 100% of the pretraining
budget -> downstream Save HIT@3 + next-item recall@10 of the pretrained
embedding space."""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import (PRETRAIN_STEPS, csv_row, data_cfg,
                               default_fcfg, finetune_and_eval, pinfm_cfg,
                               pretrain)
from repro.core.eval import next_item_recall
from repro.data.synthetic import SyntheticActivity


def main():
    data = SyntheticActivity(data_cfg())
    pcfg = pinfm_cfg()
    budget = PRETRAIN_STEPS
    for frac in (0.0, 0.25, 0.5, 1.0):
        steps = max(int(budget * frac), 1) if frac else 0
        t0 = time.perf_counter()
        if steps == 0:
            model, params, _ = pretrain(pcfg, steps=1, data=data)  # init only
            import jax
            params = model.init(jax.random.PRNGKey(0))
        else:
            model, params, _ = pretrain(pcfg, steps=steps, data=data)
        rec = next_item_recall(model, params,
                               data.pretrain_batches(8, 4, seed=777), k=10)
        m, _ = finetune_and_eval(pcfg, default_fcfg(), params, data=data)
        csv_row(f"fig3/pretrain_steps={steps}",
                (time.perf_counter() - t0) * 1e6,
                f"recall@10={rec['recall']:.3f};"
                f"save_hit3={m['save_overall']:.4f};"
                f"hide_hit3={m['hide_overall']:.4f}")


if __name__ == "__main__":
    main()
