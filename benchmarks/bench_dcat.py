"""DCAT throughput benchmark (paper §4.1: +600% serving / +200% training
over regular self-attention, +25% more from rotate-replace & skip-last).

Measures, at the paper's dedup ratios (1:10 training, 1:~100+ serving),
wall-time of scoring B_c candidates:

  baseline  — full self-attention: Ψ⁻¹-duplicated sequences + candidate
              appended, full causal forward (the FlashAttention-equivalent
              reference path);
  DCAT      — deduplicated context forward once + per-candidate crossing;
  DCAT+opt  — rotate-replace + skip-last-self-attn.

Also reports the ANALYTIC flop ratio (independent of CPU timing noise).
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, tiny_backbone
from repro.core.dcat import DCAT, DCATOptions
from repro.models.transformer import TransformerBody


def timeit(fn, *args, n=5):
    fn(*args)                       # compile + warm
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6      # us


def flop_ratio(L, Sc, ratio):
    """Analytic attention+ffn flop ratio full-self-attn : DCAT for a batch of
    B_c candidates with B_c/ratio unique users (per layer, d factors cancel).

    full: B_c sequences of length L+Sc through the transformer
    DCAT: B_u sequences of length L + B_c crossing tokens of length Sc
    """
    full = ratio * (L + Sc)
    dcat = L + ratio * Sc
    return full / dcat


def main():
    cfg = tiny_backbone().replace(n_layers=4, d_model=128, d_ff=256)
    body = TransformerBody(cfg)
    params = body.init(jax.random.PRNGKey(0))
    L, Sc = 64, 2
    d = cfg.d_model

    for mode, ratio, B_c in (("training_1:10", 10, 80),
                             ("serving_1:80", 80, 160)):
        B_u = B_c // ratio
        inv = np.repeat(np.arange(B_u), ratio).astype(np.int32)
        x_u = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (B_u, L, d))
        x_c = 0.1 * jax.random.normal(jax.random.PRNGKey(2), (B_c, Sc, d))

        dcat = DCAT(body)
        opt = DCAT(body, DCATOptions(rotate_replace=True,
                                     skip_last_self_attn=True))

        @jax.jit
        def full(x_u, x_c):
            return dcat.reference_scores(params, x_u, x_c, inv)[0]

        @jax.jit
        def dcat_fn(x_u, x_c):
            _, _, ctxs = dcat.context(params, x_u)
            return dcat.crossing(params, x_c, inv, ctxs, ctx_len=L)[0]

        @jax.jit
        def dcat_opt(x_u, x_c):
            _, _, ctxs = opt.context(params, x_u, serving=True)
            return opt.crossing(params, x_c, inv, ctxs, ctx_len=L)[0]

        t_full = timeit(full, x_u, x_c)
        t_dcat = timeit(dcat_fn, x_u, x_c)
        t_opt = timeit(dcat_opt, x_u, x_c)
        fr = flop_ratio(L, Sc, ratio)
        csv_row(f"dcat/{mode}/full_self_attn", t_full,
                f"candidates={B_c};unique={B_u}")
        csv_row(f"dcat/{mode}/dcat", t_dcat,
                f"speedup={t_full / t_dcat:.2f}x;analytic_flop_ratio={fr:.2f}x")
        csv_row(f"dcat/{mode}/dcat_opt", t_opt,
                f"speedup={t_full / t_opt:.2f}x;extra_over_dcat="
                f"{(t_dcat / t_opt - 1) * 100:.0f}%")

    # paper-scale ANALYTIC transformer-flop ratios.  The paper measures 3x
    # train / 7x serve END-TO-END — far below these bounds because the
    # non-transformer ranking stack is untouched by DCAT (Amdahl).
    csv_row("dcat/analytic/train_1:10_L256", 0,
            f"transformer_flop_ratio={flop_ratio(256, 1, 10):.1f}x;"
            f"paper_end_to_end=3x")
    csv_row("dcat/analytic/serve_1:1000_L256", 0,
            f"transformer_flop_ratio={flop_ratio(256, 1, 1000):.1f}x;"
            f"paper_end_to_end=7x")


if __name__ == "__main__":
    main()
