"""Serving engine benchmark (paper §4.3): cached vs uncached QPS on
repeat-user traffic, pipelined vs synchronous execution, and recompile
accounting across a mixed-shape request stream.

Sections:

  1. cached vs uncached — ContextCache per-user ctx KV vs the monolithic
     rank executor (context transformer re-run per call), plus the
     zero-recompile check on a mixed-shape stream.
  2. pipelined vs sync — the depth-2 host/device pipeline + device-side
     pack memo against the PR-3 synchronous path (pipeline_depth=1,
     memo_capacity=0) on a repeat-user STREAMING workload (recurring
     micro-batched compositions, multi-chunk score() calls), with a
     memo/depth ablation sweep.  Emits BENCH_serving_pipeline.json.
  3. fused two-stage vs sequential retrieve-then-rank — the
     ``RetrieveThenRankRequest`` lane (one submit, retrieval feeding the
     rank stage inside one pipeline schedule, rank operands built straight
     from retrieval-stage state) against the sequential ``retrieve()`` +
     ``score()`` shims on a repeat-user two-stage workload.  Emits
     BENCH_two_stage.json.
  4. KV slab vs host pack — the PR-6 engine (device-resident quantized
     slab + unordered pack memo) against the PR-4 host-pack path on
     PERMUTED repeat-user streaming: the same request compositions recur
     with shuffled arrival order, which PR-4's ordered memo always
     misses (so it repacks + reships every call — its memo is disabled
     here, which is behavior-equivalent on this stream) while the
     unordered memo serves via a host-side row remap.  Plus the dtype
     ablation (fp16 escape hatch / int8 / int4: score error vs bytes,
     memo-off gather-vs-pack rows) and the
     resident-users-at-fixed-arena-bytes capacity sweep.  Emits
     BENCH_kv_slab.json.  The full run executes this section in a FRESH
     interpreter (``--only-slab``, spawned automatically): the baseline's
     per-call cost is dominated by >32 MiB pack allocations whose price
     swings ~2x with inherited allocator state, so worker-process
     isolation (pyperf-style) is what makes the number reproducible —
     running ``--only-slab`` by hand gives the same result.
  5. observability overhead — the PR-7 ``repro.obs`` layer
     (``obs_enabled=True``: per-request tracing, per-lane latency
     histograms, the export collector) against the ``obs_enabled=False``
     null-object fast path on the section-2 streaming workload: scores
     bit-identical, trace JSON + Prometheus exports well-formed, and
     enabled-mode throughput within 2% of disabled (the <2% bar is the
     acceptance criterion; asserted in the full run, correctness-only in
     smoke).  Emits BENCH_obs.json.
  6. SLO lane isolation — the PR-8 per-lane flush policies on an
     ADVERSARIAL MIX: latency-sensitive rank micro-batches interleaved
     with slow large-k corpus passes queued on the retrieve lane.  With
     ``isolate_lanes=True`` a rank-threshold flush drains ONLY the rank
     lane; the ``isolate_lanes=False`` baseline (the pre-SLO shared
     flush) drags the queued corpus passes into every rank flush, so the
     rank caller pays for retrieval it never asked for.  Reports the
     rank submit->resolve latency distribution (p50/p99) both ways —
     bit-identical results, zero recompiles — plus a deterministic
     shed-pressure run (0 ms rank budget, alternating priorities: every
     sheddable request sheds with a typed ShedError, every protected one
     is served).  Emits BENCH_slo.json (in smoke too — the smoke run
     asserts the correctness half: parity, typed sheds, 0 recompiles;
     the full run also asserts the >= 1.3x rank-p99 isolation bar).

Run:   PYTHONPATH=src python benchmarks/bench_serving_engine.py [--smoke]

--smoke shrinks the traffic for CI and asserts the CORRECTNESS acceptance
properties only (cached beats uncached; pipelined scores == sync scores
bit-for-bit; fused two-stage == sequential bit-for-bit; fp16 slab ==
host pack bit-for-bit with int8/int4 inside their documented tolerance;
int8/int4 resident-capacity multipliers; obs-enabled scores == disabled
bit-for-bit with well-formed trace/Prometheus exports;
compiles_after_warmup == 0 everywhere).  The full run additionally
asserts the >= 1.3x pipelined-vs-sync, >= 1.15x fused-vs-sequential,
>= 1.3x slab-vs-host-pack and < 2% observability-overhead items/sec
acceptance bars and records the rows in the JSON files.
"""
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(os.path.dirname(__file__)))

import numpy as np
import jax

from repro.configs import smoke_config
from repro.core.dcat import DCAT, DCATOptions
from repro.core.finetune import FinetuneConfig, PinFMRankingModel
from repro.core.losses import LossConfig
from repro.core.pretrain import PinFMConfig, PinFMPretrain
from repro.models.config import get_config
from repro.retrieval import IndexBuilder
from repro.serving import (ContextCache, RankRequest, RetrieveRequest,
                           RetrieveThenRankRequest, ServingEngine)

SMOKE = "--smoke" in sys.argv

# The paper's production context length (§4.1): at toy L the context
# transformer is too cheap for caching to matter; at L=256 it dominates.
L = 256

JSON_PATH = os.path.join(os.path.dirname(__file__), "..",
                         "BENCH_serving_pipeline.json")
JSON2_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_two_stage.json")
JSON3_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_kv_slab.json")
JSON4_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_obs.json")
JSON5_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_slo.json")


def serving_model(variant="graphsage-lt", seq_len=L):
    """Bench-scale ranking model: early-fusion graphsage-lt for the cache/
    pipeline sections, lite-last for the two-stage section (retrieval +
    score_emb need the pooled-embedding paths)."""
    bb = smoke_config(get_config("pinfm-20b")).replace(
        n_layers=4, d_model=128, d_ff=256, n_heads=8, n_kv=8, head_dim=16)
    pcfg = PinFMConfig(rows=4096, n_tables=4, sub_dim=16, seq_len=seq_len,
                       loss=LossConfig(window=4, downstream_len=16,
                                       n_negatives=0))
    kw = dict(variant=variant, seq_len=seq_len, user_feat_dim=8,
              cand_feat_dim=8, hidden=64, n_cross_layers=2,
              seq_loss=LossConfig(use_mtl=False, use_ftl=False,
                                  n_negatives=0))
    if variant == "graphsage-lt":
        kw.update(graphsage_dim=16,
                  dcat=DCATOptions(rotate_replace=False,
                                   skip_last_self_attn=True))
    fcfg = FinetuneConfig(**kw)
    model = PinFMRankingModel.__new__(PinFMRankingModel)
    model.__init__(pcfg, fcfg)
    model.pinfm = PinFMPretrain(pcfg, bb)
    model.dcat = DCAT(model.pinfm.body, fcfg.dcat)
    return model, fcfg


def make_traffic(fcfg, *, n_users, n_batches, reqs_per_batch, n_cand,
                 seed=0, seq_len=L):
    """Zipf-ish repeat-user traffic: every batch draws reqs_per_batch users
    from a pool of n_users, so steady state is dominated by repeats."""
    rng = np.random.RandomState(seed)

    def mk(user_seed):
        r = np.random.RandomState(1000 + user_seed)
        return RankRequest(
            seq_ids=r.randint(0, 1500, seq_len),
            seq_actions=r.randint(0, 6, seq_len),
            seq_surfaces=r.randint(0, 3, seq_len),
            cand_ids=rng.randint(0, 1500, n_cand),
            cand_feats=rng.randn(n_cand, fcfg.cand_feat_dim)
            .astype(np.float32),
            user_feats=np.random.RandomState(1000 + user_seed)
            .randn(fcfg.user_feat_dim).astype(np.float32),
            graphsage=rng.randn(n_cand, fcfg.graphsage_dim)
            .astype(np.float32))

    return [[mk(int(u)) for u in rng.randint(0, n_users, reqs_per_batch)]
            for _ in range(n_batches)]


def drive(engine, traffic):
    t0 = time.time()
    n_cand = 0
    for batch in traffic:
        out = engine.score(batch)
        n_cand += sum(len(o) for o in out)
    dt = time.time() - t0
    return n_cand / dt, dt


# ---------------------------------------------------------------------------
# section 1: cached vs uncached (PR-1 acceptance, kept as regression)
# ---------------------------------------------------------------------------

def section_cached_vs_uncached(model, params, fcfg):
    n_batches = 4 if SMOKE else 24
    traffic = make_traffic(fcfg, n_users=6, n_batches=n_batches,
                           reqs_per_batch=6, n_cand=8)

    kw = dict(max_unique=8, max_candidates=64, min_unique=4,
              min_candidates=32)
    uncached = ServingEngine(model, params, **kw)
    cached = ServingEngine(model, params, cache=ContextCache(4096), **kw)
    tu = uncached.warmup()
    tc = cached.warmup()
    print(f"warmup: uncached {tu['executors']} executors {tu['warmup_s']:.1f}s"
          f" | cached {tc['executors']} executors {tc['warmup_s']:.1f}s")

    # prime the cache with one pass, then measure steady-state repeat traffic
    cached.score(traffic[0][:])
    qps_u, dt_u = drive(uncached, traffic)
    qps_c, dt_c = drive(cached, traffic)
    ratio = cached.cache.hits / max(cached.cache.hits + cached.cache.misses, 1)
    print(f"uncached: {qps_u:9.0f} candidates/s ({dt_u * 1e3:6.1f} ms total)")
    print(f"cached:   {qps_c:9.0f} candidates/s ({dt_c * 1e3:6.1f} ms total, "
          f"hit rate {ratio * 100:.0f}%, "
          f"{cached.cache.nbytes / 2**20:.1f} MiB ctx KV)")
    print(f"speedup:  {qps_c / qps_u:.2f}x on repeat-user traffic")

    # recompile accounting on a mixed-shape stream
    rng = np.random.RandomState(7)
    mixed = [t[:int(n)] for t, n in zip(traffic, rng.randint(1, 7, n_batches))]
    for batch in mixed:
        uncached.score(batch)
        cached.score(batch)
    rec_u = uncached.registry.compiles_after_warmup
    rec_c = cached.registry.compiles_after_warmup
    for batch in mixed:                         # second pass
        uncached.score(batch)
        cached.score(batch)
    print(f"recompiles after warmup (mixed-shape stream, 2 passes): "
          f"uncached {uncached.registry.compiles_after_warmup}, "
          f"cached {cached.registry.compiles_after_warmup}")

    assert cached.registry.compiles_after_warmup == 0 == rec_c
    assert uncached.registry.compiles_after_warmup == 0 == rec_u
    if not SMOKE:
        # timing assertion gated OUT of smoke: two sequential 4-batch
        # drives on a loaded shared CI runner can invert on scheduling
        # noise — CI gates correctness (recompiles/parity) only
        assert qps_c > qps_u, (
            f"ContextCache path ({qps_c:.0f}/s) must beat the uncached "
            f"path ({qps_u:.0f}/s) on repeat-user traffic")
    print("OK: cached vs uncached measured, zero recompiles after warmup")
    return {"uncached_items_per_s": qps_u, "cached_items_per_s": qps_c,
            "cache_speedup": qps_c / qps_u, "cache_hit_rate": ratio}


# ---------------------------------------------------------------------------
# section 2: pipelined vs sync (this PR's acceptance)
# ---------------------------------------------------------------------------

def _pipeline_workload(fcfg):
    """Repeat-user STREAMING workload: a pool of micro-batched compositions
    recurs (the micro-batcher's steady state — the same coalesced batches
    of repeat users come around again and again), and each score() call
    spans several chunks so the depth-2 pipeline has chunks to overlap."""
    if SMOKE:
        kw = dict(max_unique=4, max_candidates=32, min_unique=4,
                  min_candidates=32)
        base = make_traffic(fcfg, n_users=4, n_batches=3, reqs_per_batch=8,
                            n_cand=8, seed=3)
        stream = [base[i % len(base)] for i in range(6)]
        reps = 1
    else:
        kw = dict(max_unique=32, max_candidates=32, min_unique=32,
                  min_candidates=32)
        base = make_traffic(fcfg, n_users=32, n_batches=6,
                            reqs_per_batch=32, n_cand=2, seed=3)
        stream = [base[i % len(base)] for i in range(18)]
        reps = 5
    return kw, base, stream, reps


def _make_row_engine(model, params, base, stream, kw, *, name, depth,
                     memo_capacity, parity_ref=None):
    """Build + warm an engine, prime it over the distinct compositions, and
    check score parity on the whole stream.  -> (engine, row-config dict,
    parity outputs)."""
    engine = ServingEngine(
        model, params, cache=ContextCache(4096, memo_capacity=memo_capacity),
        pipeline_depth=depth, **kw)
    engine.warmup()
    for b in base:                                  # prime user cache + memo
        engine.score(b)
    outs = [engine.score(b) for b in stream]        # parity + warm pass
    if parity_ref is not None:
        for ref_call, got_call in zip(parity_ref, outs):
            for r, g in zip(ref_call, got_call):
                np.testing.assert_array_equal(r, g)
    return engine, {"name": name, "pipeline_depth": depth,
                    "memo_capacity": memo_capacity}, outs


def _finish_row(engine, row, qs, n_calls):
    """Fold the interleaved drive measurements + telemetry into the row."""
    qs = sorted(qs)
    ps = engine.pipeline_stats[-n_calls:]
    memo = engine.cache.stats()
    hit_rate = (memo["memo_hits"]
                / max(memo["memo_hits"] + memo["memo_misses"], 1))
    row.update({
        "items_per_s": qs[len(qs) // 2],
        "items_per_s_all": [round(q, 1) for q in qs],
        "memo_hit_rate": round(hit_rate, 4),
        "overlap_fraction": round(float(np.mean(
            [p.overlap_fraction for p in ps])), 4),
        "prepare_ms_per_call": round(float(np.mean(
            [p.prepare_ms for p in ps])), 3),
        "wait_ms_per_call": round(float(np.mean(
            [p.wait_ms for p in ps])), 3),
        "chunks_per_call": round(float(np.mean([p.chunks for p in ps])), 2),
        "compiles_after_warmup": engine.registry.compiles_after_warmup,
    })
    assert engine.registry.compiles_after_warmup == 0, row
    return row


def section_pipelined_vs_sync(model, params, fcfg):
    kw, base, stream, reps = _pipeline_workload(fcfg)
    print(f"\npipelined vs sync: {len(stream)} calls of "
          f"{len(stream[0])} requests, buckets (b_u={kw['max_unique']}, "
          f"b_c={kw['max_candidates']}), median of {reps} interleaved")

    # the PR-3 synchronous path: no pipeline, no pack memo
    sync_engine, sync_row, sync_outs = _make_row_engine(
        model, params, base, stream, kw,
        name="sync (PR-3 path)", depth=1, memo_capacity=0)
    # this PR's engine + the ablation/memo-hit sweep; every variant's
    # scores must match the sync path BIT-FOR-BIT
    variants = [(sync_engine, sync_row)]
    for name, depth, memo in (("pipelined + memo", 2, 64),
                              ("memo only", 1, 64),
                              ("pipeline only", 2, 0),
                              ("memo thrash (LRU < working set)", 2, 4)):
        engine, row, _ = _make_row_engine(
            model, params, base, stream, kw, name=name, depth=depth,
            memo_capacity=memo, parity_ref=sync_outs)
        variants.append((engine, row))

    # INTERLEAVED timing: all engines are driven once per round, so
    # process-level drift (allocator state, CPU frequency) hits every
    # variant equally and the RATIOS stay trustworthy
    qs = [[] for _ in variants]
    for _ in range(reps):
        for i, (engine, _) in enumerate(variants):
            qs[i].append(drive(engine, stream)[0])
    sweep = [_finish_row(engine, row, q, len(stream))
             for (engine, row), q in zip(variants, qs)]
    sync_row, pipe_row = sweep[0], sweep[1]

    speedup = pipe_row["items_per_s"] / sync_row["items_per_s"]
    for row in sweep:
        print(f"  {row['name']:34s} {row['items_per_s']:8.0f} items/s  "
              f"(x{row['items_per_s'] / sync_row['items_per_s']:.2f}, "
              f"memo hit {row['memo_hit_rate'] * 100:3.0f}%, "
              f"overlap {row['overlap_fraction'] * 100:3.0f}%)")
    print(f"pipelined speedup: {speedup:.2f}x over the synchronous path "
          f"(scores bit-identical, 0 recompiles)")
    if not SMOKE:
        assert speedup >= 1.3, (
            f"acceptance: pipelined engine must reach >= 1.3x the "
            f"synchronous path, got {speedup:.2f}x")
    return {"workload": {
                "calls": len(stream), "requests_per_call": len(stream[0]),
                "distinct_compositions": len(base), "seq_len": L,
                **{k: kw[k] for k in ("max_unique", "max_candidates")}},
            "rows": sweep, "pipelined_speedup_vs_sync": speedup,
            "score_parity": "bit-identical (sync vs pipelined vs ablations)"}


# ---------------------------------------------------------------------------
# section 3: fused two-stage vs sequential retrieve-then-rank
# ---------------------------------------------------------------------------

def section_two_stage():
    model, fcfg = serving_model(variant="lite-last")
    params = model.init(jax.random.PRNGKey(0))
    n_items = 4096 if SMOKE else 32768
    top_k = 8 if SMOKE else 16
    n_pool = 8 if SMOKE else 16
    n_calls, stream_len, reps = (3, 4, 1) if SMOKE else (4, 12, 5)
    index = IndexBuilder(model, params, batch_size=4096, bits=4) \
        .build(0, n_items)
    feat_table = np.random.RandomState(0) \
        .randn(n_items, fcfg.cand_feat_dim).astype(np.float32)
    feats = lambda ids: feat_table[np.asarray(ids)]

    def user(seed):
        r = np.random.RandomState(1000 + seed)
        return (r.randint(0, n_items, L), r.randint(0, 6, L),
                r.randint(0, 3, L),
                r.randn(fcfg.user_feat_dim).astype(np.float32))

    pool = [user(s) for s in range(n_pool)]
    rng = np.random.RandomState(3)
    calls = [[pool[u] for u in rng.randint(0, n_pool, 16)]
             for _ in range(n_calls)]
    stream = [calls[i % len(calls)] for i in range(stream_len)]
    print(f"\nfused two-stage vs sequential: {stream_len} calls of "
          f"{len(calls[0])} requests, corpus {n_items} items, top-{top_k}, "
          f"median of {reps} interleaved")

    def two_reqs(call):
        return [RetrieveThenRankRequest(
                    seq_ids=i, seq_actions=a, seq_surfaces=s, user_feats=uf,
                    k=top_k) for i, a, s, uf in call]

    def mk_engine():
        e = ServingEngine(model, params, max_unique=8, max_candidates=64,
                          min_unique=8, min_candidates=64,
                          cache=ContextCache(4096))
        e.attach_index(index, k=top_k, chunk_rows=8192)
        e.attach_features(feats)
        e.warmup()
        for c in calls:                               # prime the user cache
            futs = e.submit_many(two_reqs(c))
            e.flush()
            for f in futs:
                f.result()
        return e

    def run_fused(e, call):
        futs = e.submit_many(two_reqs(call))
        e.flush()
        return [f.result() for f in futs]

    def run_seq(e, call):
        got = e.retrieve([RetrieveRequest(
            seq_ids=i, seq_actions=a, seq_surfaces=s, k=top_k)
            for i, a, s, _ in call])
        probs = e.score([RankRequest(
            seq_ids=i, seq_actions=a, seq_surfaces=s, cand_ids=ids,
            cand_feats=feats(ids), user_feats=uf)
            for (i, a, s, uf), (ids, _) in zip(call, got)])
        return got, probs

    fused_e, seq_e = mk_engine(), mk_engine()

    # parity: fused == sequential BIT-FOR-BIT on every call composition
    for call in calls:
        fres = run_fused(fused_e, call)
        got, probs = run_seq(seq_e, call)
        for r, (ids, sc), p in zip(fres, got, probs):
            np.testing.assert_array_equal(r.item_ids, ids)
            np.testing.assert_array_equal(r.retrieval_scores, sc)
            np.testing.assert_array_equal(r.probs, p)

    def drive_two_stage(run, e):
        t0 = time.time()
        n = 0
        for call in stream:
            out = run(e, call)
            n += 16 * top_k
        return n / (time.time() - t0)

    qs_f, qs_s = [], []
    for _ in range(reps):                    # interleaved: drift-fair ratios
        qs_s.append(drive_two_stage(run_seq, seq_e))
        qs_f.append(drive_two_stage(run_fused, fused_e))
    qs_f, qs_s = sorted(qs_f), sorted(qs_s)
    items_f, items_s = qs_f[len(qs_f) // 2], qs_s[len(qs_s) // 2]
    speedup = items_f / items_s
    ps = [p for p in fused_e.pipeline_stats if p.lane == "two_stage"]
    assert fused_e.registry.compiles_after_warmup == 0
    assert seq_e.registry.compiles_after_warmup == 0
    print(f"  sequential retrieve()+score() {items_s:8.0f} items/s")
    print(f"  fused RetrieveThenRankRequest {items_f:8.0f} items/s  "
          f"(x{speedup:.2f})")
    print(f"fused two-stage speedup: {speedup:.2f}x over sequential "
          f"(bit-identical results, 0 recompiles)")
    if not SMOKE:
        assert speedup >= 1.15, (
            f"acceptance: fused two-stage must reach >= 1.15x the "
            f"sequential path, got {speedup:.2f}x")
    return {"workload": {
                "calls": stream_len, "requests_per_call": 16,
                "distinct_compositions": len(calls), "pool_users": n_pool,
                "corpus_items": n_items, "top_k": top_k, "seq_len": L},
            "sequential_items_per_s": items_s,
            "fused_items_per_s": items_f,
            "fused_items_per_s_all": [round(q, 1) for q in qs_f],
            "sequential_items_per_s_all": [round(q, 1) for q in qs_s],
            "fused_speedup_vs_sequential": speedup,
            "retrieve_ms_per_call": round(float(np.mean(
                [p.retrieve_ms for p in ps])), 3),
            "rank_prepare_ms_per_call": round(float(np.mean(
                [p.prepare_ms for p in ps])), 3),
            "score_parity": "bit-identical (fused vs sequential)"}


# ---------------------------------------------------------------------------
# section 4: device-resident quantized KV slab vs host pack
# ---------------------------------------------------------------------------

def section_kv_slab(model, params, fcfg):
    from repro.serving.kv_slab import KVSlab

    if SMOKE:
        n_users, n_comps, stream_len, reps, L_s = 8, 2, 6, 1, L
        kw = dict(max_unique=8, max_candidates=32, min_unique=8,
                  min_candidates=32)
    else:
        # L=512: per-user ctx KV doubles vs the other sections — the regime
        # the slab exists for (resident KV bytes dominating the pack path)
        n_users, n_comps, stream_len, reps, L_s = 32, 3, 18, 5, 512
        kw = dict(max_unique=32, max_candidates=32, min_unique=32,
                  min_candidates=32)
        model, fcfg = serving_model(seq_len=L_s)
        params = model.init(jax.random.PRNGKey(0))
    base = make_traffic(fcfg, n_users=n_users, n_batches=n_comps,
                        reqs_per_batch=n_users, n_cand=1, seed=5,
                        seq_len=L_s)
    # PERMUTED repeat stream: the same compositions recur with shuffled
    # arrival order — the dominant steady state under cross-caller
    # coalescing, and the case PR-4's ordered memo keys always miss
    prm = np.random.RandomState(11)
    stream = [[base[i % n_comps][j] for j in prm.permutation(n_users)]
              for i in range(stream_len)]
    print(f"\nKV slab vs host pack: {stream_len} permuted-order calls of "
          f"{n_users} requests ({n_comps} recurring compositions), L={L_s}, "
          f"median of {reps} interleaved")

    def mk_engine(name, memo, **skw):
        # memo=0 on the host-pack baseline is behavior-equivalent to the
        # PR-4 ordered memo on this stream (permuted arrivals never hit
        # an ordered key, so PR-4 repacks + reships every call)
        e = ServingEngine(model, params,
                          cache=ContextCache(4096, memo_capacity=memo),
                          **kw, **skw)
        e.warmup()
        for b in base:                           # seat the pool of users
            e.score(b)
        return e, {"name": name, "memo_capacity": memo,
                   **{k: str(v) for k, v in skw.items()}}

    host_e, host_row = mk_engine("host pack (PR-4 path)", 0)
    slabs = [mk_engine(f"slab {d}", 0, slab_slots=n_users, slab_dtype=d)
             for d in ("fp16", "int8", "int4")]
    pr6_e, pr6_row = mk_engine("slab int8 + unordered memo (PR-6)", 64,
                               slab_slots=n_users, slab_dtype="int8")

    # -- parity: fp16 escape hatch bit-identical; quantized inside tolerance
    ref = [host_e.score(b) for b in stream]
    tol = {"fp16": 0.0, "int8": 5e-3, "int4": 5e-2}
    for e, row in slabs + [(pr6_e, pr6_row)]:
        err = 0.0
        for ref_call, b in zip(ref, stream):
            for r, g in zip(ref_call, e.score(b)):
                err = max(err, float(np.max(np.abs(r - g))))
        d = row["slab_dtype"]
        assert err <= tol[d], (d, err)
        row["max_abs_prob_err_vs_host_pack"] = err
        print(f"  {row['name']:33s} max |dp| vs host pack {err:.2e} "
              f"(tolerance {tol[d]:.0e})")

    # -- throughput: host pack vs slab dtypes vs the full PR-6 engine,
    #    interleaved rounds
    engines = [(host_e, host_row)] + slabs + [(pr6_e, pr6_row)]
    qs = [[] for _ in engines]
    for _ in range(reps):
        for i, (e, _) in enumerate(engines):
            qs[i].append(drive(e, stream)[0])
    for (e, row), q in zip(engines, qs):
        q = sorted(q)
        row["items_per_s"] = q[len(q) // 2]
        row["items_per_s_all"] = [round(v, 1) for v in q]
        row["compiles_after_warmup"] = e.registry.compiles_after_warmup
        assert e.registry.compiles_after_warmup == 0, row
        row["memo_perm_hits"] = e.memo_perm_hits
        s = e.stats()["slab"]
        if s is not None:
            row["slab_stats"] = {k: s[k] for k in
                                 ("capacity", "occupancy", "puts",
                                  "evictions", "gathers", "bytes_resident",
                                  "bytes_per_user", "fallbacks")}
        ratio = row["items_per_s"] / host_row["items_per_s"]
        print(f"  {row['name']:33s} {row['items_per_s']:8.0f} items/s  "
              f"(x{ratio:.2f} vs host pack)")
    assert pr6_row["memo_perm_hits"] > 0          # the stream really permutes
    speedup = pr6_row["items_per_s"] / host_row["items_per_s"]
    print(f"PR-6 speedup: {speedup:.2f}x over the PR-4 host-pack path on "
          f"permuted repeat-user streaming (zero context bytes moved on "
          f"the hit path)")
    if not SMOKE:
        assert speedup >= 1.3, (
            f"acceptance: slab + unordered memo must reach >= 1.3x the "
            f"host-pack path on permuted repeat-user streaming, got "
            f"{speedup:.2f}x")

    # -- resident capacity at fixed arena bytes ----------------------------
    # the escape hatch stores the NATIVE ctx dtype (fp32 here — that is
    # what bit-identity to the host-pack path requires), so the honest
    # comparison is quantized vs unquantized resident bytes per user
    budget = 1 << 30                                      # 1 GiB arena
    cap_rows = []
    for d in ("fp16", "int8", "int4"):
        bpu = KVSlab(model, params, seq_len=L_s, slots=1,
                     dtype=d).bytes_per_user
        cap_rows.append({"dtype": d, "bytes_per_user": bpu,
                         "resident_users_per_GiB": budget // bpu})
    base_row = cap_rows[0]
    for row in cap_rows:
        row["capacity_multiplier"] = (base_row["bytes_per_user"]
                                      / row["bytes_per_user"])
        print(f"  {row['dtype']:5s} {row['bytes_per_user']:8d} B/user  "
              f"{row['resident_users_per_GiB']:8d} users/GiB  "
              f"(x{row['capacity_multiplier']:.2f})")
    assert cap_rows[1]["capacity_multiplier"] >= 3.0, cap_rows
    assert cap_rows[2]["capacity_multiplier"] >= 4.0, cap_rows
    print("OK: fp16 slab == host pack bit-for-bit, int8/int4 in tolerance, "
          "capacity multipliers hold, zero recompiles")
    return {"workload": {
                "calls": stream_len, "requests_per_call": n_users,
                "recurring_compositions": n_comps, "arrival_order":
                "permuted per call", "pool_users": n_users,
                "slab_slots": n_users, "seq_len": L_s,
                **{k: kw[k] for k in ("max_unique", "max_candidates")}},
            "rows": [row for _, row in engines],
            "pr6_speedup_vs_host_pack": speedup,
            "resident_capacity_at_fixed_bytes": cap_rows,
            "score_parity": ("fp16 slab bit-identical to host pack; "
                             "int8 <= 5e-3, int4 <= 5e-2 max |dp|")}


# ---------------------------------------------------------------------------
# section 5: observability overhead (obs on vs off)
# ---------------------------------------------------------------------------

def section_observability(model, params, fcfg):
    """The PR-7 acceptance: an obs-enabled engine must (a) score
    bit-identically to a disabled one, (b) export a Perfetto-loadable
    trace and well-formed Prometheus text with per-lane p50/p99 flush
    latency, (c) keep ``compiles_after_warmup == 0`` under tracing, and
    (d) cost < 2% items/sec vs the ``obs_enabled=False`` null-object
    fast path on the section-2 streaming workload."""
    kw, base, stream, reps = _pipeline_workload(fcfg)
    reps = 1 if SMOKE else max(reps, 5)
    print(f"\nobservability overhead: {len(stream)} calls of "
          f"{len(stream[0])} requests, obs on vs off, median of {reps} "
          "interleaved")

    def mk(enabled):
        e = ServingEngine(model, params,
                          cache=ContextCache(4096, memo_capacity=64),
                          pipeline_depth=2, obs_enabled=enabled, **kw)
        e.warmup()
        for b in base:                       # prime user cache + pack memo
            e.score(b)
        return e

    on_e, off_e = mk(True), mk(False)

    # -- parity: tracing must not perturb results at all --------------------
    for b in stream:
        for r, g in zip(off_e.score(b), on_e.score(b)):
            np.testing.assert_array_equal(r, g)

    # -- interleaved timing: drift-fair on/off ratio ------------------------
    qs_on, qs_off = [], []
    for _ in range(reps):
        qs_off.append(drive(off_e, stream)[0])
        qs_on.append(drive(on_e, stream)[0])
    qs_on, qs_off = sorted(qs_on), sorted(qs_off)
    items_on, items_off = qs_on[len(qs_on) // 2], qs_off[len(qs_off) // 2]
    overhead = 1.0 - items_on / items_off

    # -- functional acceptance (asserted in smoke too) ----------------------
    assert on_e.registry.compiles_after_warmup == 0    # tracing != compiles
    assert off_e.registry.compiles_after_warmup == 0
    trace = on_e.obs.chrome_trace()
    json.dumps(trace)                                  # serializable
    names = {ev["name"] for ev in trace["traceEvents"]}
    assert {"flush", "lane:rank", "prepare", "launch", "wait",
            "RankRequest"} <= names, sorted(names)
    prom = on_e.obs.prometheus_text()
    assert "repro_serving_flush_latency_ms_bucket" in prom
    assert 'repro_serving_flush_latency_ms_p50{lane="rank"}' in prom
    assert 'repro_serving_flush_latency_ms_p99{lane="rank"}' in prom
    assert "repro_serving_executor_compiles_after_warmup 0" in prom
    assert "repro_serving_memo_hits_total" in prom
    # the disabled engine's exports are EMPTY, not merely small
    assert off_e.obs.prometheus_text() == ""
    assert off_e.obs.chrome_trace()["traceEvents"] == []

    n_events = len(trace["traceEvents"])
    print(f"  obs off (null objects)  {items_off:8.0f} items/s")
    print(f"  obs on  (trace+metrics) {items_on:8.0f} items/s  "
          f"({overhead * 100:+.1f}% overhead, {n_events} trace events, "
          f"dropped {trace['otherData']['dropped_events']})")
    print("observability: scores bit-identical, exports well-formed, "
          "0 recompiles under tracing")
    if not SMOKE:
        # timing gated out of smoke like every other section; the 2% bar
        # is the PR acceptance criterion
        assert items_on >= 0.98 * items_off, (
            f"acceptance: obs-enabled engine must stay within 2% of the "
            f"disabled fast path, got {overhead * 100:.1f}% overhead "
            f"({items_on:.0f} vs {items_off:.0f} items/s)")
    return {"workload": {
                "calls": len(stream), "requests_per_call": len(stream[0]),
                "seq_len": L,
                **{k: kw[k] for k in ("max_unique", "max_candidates")}},
            "obs_off_items_per_s": items_off,
            "obs_on_items_per_s": items_on,
            "obs_off_items_per_s_all": [round(q, 1) for q in qs_off],
            "obs_on_items_per_s_all": [round(q, 1) for q in qs_on],
            "overhead_fraction": round(overhead, 4),
            "trace_events": n_events,
            "score_parity": "bit-identical (obs on vs off)"}


# ---------------------------------------------------------------------------
# section 6: SLO lane isolation — rank latency under an adversarial mix
# ---------------------------------------------------------------------------

def section_slo():
    from repro.serving import LanePolicy, ShedError

    model, fcfg = serving_model(variant="lite-last")
    params = model.init(jax.random.PRNGKey(0))
    if SMOKE:
        n_items, top_k, chunk_rows = 2048, 8, 2048
        n_rounds, n_retr, n_rank = 6, 3, 4
    else:
        n_items, top_k, chunk_rows = 32768, 16, 8192
        n_rounds, n_retr, n_rank = 30, 6, 4
    index = IndexBuilder(model, params, batch_size=4096, bits=4) \
        .build(0, n_items)
    feat_table = np.random.RandomState(0) \
        .randn(n_items, fcfg.cand_feat_dim).astype(np.float32)
    feats = lambda ids: feat_table[np.asarray(ids)]
    print(f"\nSLO lane isolation: {n_rounds} rounds of {n_retr} queued "
          f"corpus passes (top-{top_k} over {n_items} items) + {n_rank} "
          f"latency-sensitive rank requests, isolated vs shared flush")

    def user(seed):
        r = np.random.RandomState(1000 + seed)
        return (r.randint(0, n_items, L), r.randint(0, 6, L),
                r.randint(0, 3, L),
                r.randn(fcfg.user_feat_dim).astype(np.float32))

    pool = [user(s) for s in range(8)]

    def mk_rank(rnd, j, priority=0):
        i, a, s, uf = pool[(rnd * 3 + j) % len(pool)]
        r = np.random.RandomState(500 + rnd * 16 + j)
        ids = r.randint(0, n_items, 4)
        return RankRequest(seq_ids=i, seq_actions=a, seq_surfaces=s,
                           cand_ids=ids, cand_feats=feats(ids),
                           user_feats=uf, priority=priority)

    def mk_retrieve(rnd, j):
        i, a, s, _ = pool[(rnd * 5 + j + 3) % len(pool)]
        return RetrieveRequest(seq_ids=i, seq_actions=a, seq_surfaces=s,
                               k=top_k)

    def mk_engine(isolate, policies=None):
        e = ServingEngine(
            model, params, max_unique=8, max_candidates=64,
            min_unique=4, min_candidates=32, cache=ContextCache(4096),
            max_pending=100, isolate_lanes=isolate,
            lane_policies=policies if policies is not None
            else {"rank": LanePolicy(max_requests=n_rank)})
        e.attach_index(index, k=top_k, chunk_rows=chunk_rows)
        e.attach_features(feats)
        e.warmup()
        for rnd in range(min(n_rounds, 3)):          # prime the user cache
            e.submit_many([mk_retrieve(rnd, j) for j in range(n_retr)]
                          + [mk_rank(rnd, j) for j in range(n_rank)])
            e.flush()
        return e

    def run_round(engine, rnd):
        """One adversarial round: queue the corpus passes, then submit the
        rank micro-batch — the n_rank-th submit trips the rank lane's
        threshold and flushes inline.  Isolated: that flush serves ONLY
        the rank requests; shared: it drags the queued corpus passes in.
        -> (per-rank-request latencies ms, rank results, retrieve results)."""
        retr_futs = [engine.submit(mk_retrieve(rnd, j))
                     for j in range(n_retr)]
        t_sub, rank_futs = [], []
        for j in range(n_rank):
            t_sub.append(time.perf_counter())
            rank_futs.append(engine.submit(mk_rank(rnd, j)))
        t_done = time.perf_counter()
        assert all(f.done() for f in rank_futs)      # flushed inline
        engine.flush()                               # drain the retrieve lane
        return ([(t_done - t) * 1e3 for t in t_sub],
                [f.result() for f in rank_futs],
                [f.result() for f in retr_futs])

    iso_e, shared_e = mk_engine(True), mk_engine(False)
    lat_iso, lat_shared = [], []
    for rnd in range(n_rounds):                      # interleaved: drift-fair
        l_s, rank_s, retr_s = run_round(shared_e, rnd)
        l_i, rank_i, retr_i = run_round(iso_e, rnd)
        lat_shared.extend(l_s)
        lat_iso.extend(l_i)
        # parity: lane isolation must not change a single bit
        for a, b in zip(rank_i, rank_s):
            np.testing.assert_array_equal(a, b)
        for (ia, sa), (ib, sb) in zip(retr_i, retr_s):
            np.testing.assert_array_equal(ia, ib)
            np.testing.assert_array_equal(sa, sb)
    assert iso_e.registry.compiles_after_warmup == 0
    assert shared_e.registry.compiles_after_warmup == 0
    assert iso_e.scheduler.shed_total == 0 == shared_e.scheduler.shed_total

    pct = lambda xs, q: float(np.percentile(np.asarray(xs), q))
    p50_i, p99_i = pct(lat_iso, 50), pct(lat_iso, 99)
    p50_s, p99_s = pct(lat_shared, 50), pct(lat_shared, 99)
    p99_ratio = p99_s / p99_i
    print(f"  shared flush (pre-SLO)  rank p50 {p50_s:7.2f} ms  "
          f"p99 {p99_s:7.2f} ms")
    print(f"  isolated lanes (PR-8)   rank p50 {p50_i:7.2f} ms  "
          f"p99 {p99_i:7.2f} ms")
    print(f"rank-lane p99 improvement: {p99_ratio:.2f}x (results "
          f"bit-identical, 0 recompiles, nothing shed)")
    if not SMOKE:
        assert p99_ratio >= 1.3, (
            f"acceptance: lane isolation must improve rank p99 >= 1.3x "
            f"over the shared flush, got {p99_ratio:.2f}x")

    # -- deterministic shed pressure: 0 ms rank budget, alternating
    #    priorities — sheddable requests shed with a typed ShedError,
    #    protected ones ride the same flush to a real score
    shed_e = mk_engine(True, policies={
        "rank": LanePolicy(max_requests=n_rank, shed_ms=0.0,
                           shed_max_priority=0)})
    shed_before = shed_e.scheduler.shed_total    # priming also sheds prio-0
    n_shed = n_served = 0
    for rnd in range(n_rounds):
        futs = [shed_e.submit(mk_rank(rnd, j, priority=j % 2))
                for j in range(n_rank)]
        shed_e.flush()
        for j, f in enumerate(futs):
            try:
                f.result()
                n_served += 1
                assert j % 2 == 1, "sheddable request escaped the 0ms budget"
            except ShedError as e:
                n_shed += 1
                assert e.lane == "rank" and e.reason == "deadline"
                assert j % 2 == 0, "protected request was shed"
    assert n_shed == n_rounds * (n_rank // 2), (n_shed, n_rounds, n_rank)
    assert shed_e.scheduler.shed_total - shed_before == n_shed
    assert shed_e.registry.compiles_after_warmup == 0
    lane = shed_e.stats()["scheduler"]["lane_detail"]["rank"]
    print(f"shed pressure: {n_shed} shed (typed ShedError), {n_served} "
          f"protected served, {lane['deadline_misses']} deadline misses "
          f"recorded")

    res = {"workload": {
               "rounds": n_rounds, "rank_per_round": n_rank,
               "retrieve_per_round": n_retr, "corpus_items": n_items,
               "top_k": top_k, "chunk_rows": chunk_rows, "seq_len": L},
           "rank_p50_ms_isolated": round(p50_i, 3),
           "rank_p99_ms_isolated": round(p99_i, 3),
           "rank_p50_ms_shared": round(p50_s, 3),
           "rank_p99_ms_shared": round(p99_s, 3),
           "rank_p99_improvement": round(p99_ratio, 3),
           "shed_pressure": {"shed": n_shed, "served": n_served,
                             "deadline_misses": lane["deadline_misses"]},
           "score_parity": "bit-identical (isolated vs shared flush)"}
    # emitted in smoke too: CI gates on this file existing + the
    # correctness fields; the full run overwrites it with real latencies
    out = {"bench": "slo_lane_isolation", "smoke": SMOKE,
           "device": jax.devices()[0].platform,
           "cpu_count": os.cpu_count(), **res}
    with open(JSON5_PATH, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {os.path.relpath(JSON5_PATH)}")
    return res


def _slab_only():
    # fresh-interpreter entry point for section 4 (spawned by main() in
    # full mode; see the module docstring for why isolation matters here).
    # section_kv_slab builds its own L=512 model in full mode, so the
    # shared model is not needed.
    res = section_kv_slab(None, None, None)
    out3 = {"bench": "kv_slab", "smoke": False,
            "device": jax.devices()[0].platform,
            "cpu_count": os.cpu_count(), **res}
    with open(JSON3_PATH, "w") as f:
        json.dump(out3, f, indent=2)
    print(f"wrote {os.path.relpath(JSON3_PATH)}")


def main():
    model, fcfg = serving_model()
    params = model.init(jax.random.PRNGKey(0))

    cache_res = section_cached_vs_uncached(model, params, fcfg)
    pipe_res = section_pipelined_vs_sync(model, params, fcfg)
    obs_res = section_observability(model, params, fcfg)
    if SMOKE:
        section_kv_slab(model, params, fcfg)
    else:
        import subprocess
        subprocess.run([sys.executable, os.path.abspath(__file__),
                        "--only-slab"], check=True)
    two_stage_res = section_two_stage()
    section_slo()                    # writes BENCH_slo.json itself

    if not SMOKE:
        out = {"bench": "serving_pipeline", "smoke": False,
               "device": jax.devices()[0].platform,
               "cpu_count": os.cpu_count(),
               "cached_vs_uncached": cache_res, **pipe_res}
        with open(JSON_PATH, "w") as f:
            json.dump(out, f, indent=2)
        print(f"wrote {os.path.relpath(JSON_PATH)}")
        out2 = {"bench": "two_stage", "smoke": False,
                "device": jax.devices()[0].platform,
                "cpu_count": os.cpu_count(), **two_stage_res}
        with open(JSON2_PATH, "w") as f:
            json.dump(out2, f, indent=2)
        print(f"wrote {os.path.relpath(JSON2_PATH)}")
        out4 = {"bench": "obs_overhead", "smoke": False,
                "device": jax.devices()[0].platform,
                "cpu_count": os.cpu_count(), **obs_res}
        with open(JSON4_PATH, "w") as f:
            json.dump(out4, f, indent=2)
        print(f"wrote {os.path.relpath(JSON4_PATH)}")
    print("OK: pipelined == sync bit-for-bit, slab fp16 == host pack "
          "bit-for-bit, fused two-stage == sequential bit-for-bit, obs "
          "on == off bit-for-bit, zero recompiles after warmup")


if __name__ == "__main__":
    if "--only-slab" in sys.argv:
        _slab_only()
    else:
        main()
