"""Serving engine benchmark (paper §4.3): cached vs uncached QPS on
repeat-user traffic, plus recompile accounting across a mixed-shape
request stream.

  uncached — monolithic rank executor: context transformer + crossing on
             every call (the seed router's steady state);
  cached   — ContextCache holds per-user context KV; repeat-user traffic
             skips the context transformer and goes straight to DCAT
             crossing.

Run:   PYTHONPATH=src python benchmarks/bench_serving_engine.py [--smoke]

--smoke shrinks the traffic for CI: it still asserts the two acceptance
properties (cached beats uncached on repeat traffic; zero recompiles on
the second pass of a mixed-shape stream after warmup()).
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(os.path.dirname(__file__)))

import numpy as np
import jax

from repro.configs import smoke_config
from repro.core.dcat import DCAT, DCATOptions
from repro.core.finetune import FinetuneConfig, PinFMRankingModel
from repro.core.losses import LossConfig
from repro.core.pretrain import PinFMConfig, PinFMPretrain
from repro.models.config import get_config
from repro.serving import ContextCache, RankRequest, ServingEngine

SMOKE = "--smoke" in sys.argv

# The paper's production context length (§4.1): at toy L the context
# transformer is too cheap for caching to matter; at L=256 it dominates.
L = 256


def serving_model():
    bb = smoke_config(get_config("pinfm-20b")).replace(
        n_layers=4, d_model=128, d_ff=256, n_heads=8, n_kv=8, head_dim=16)
    pcfg = PinFMConfig(rows=4096, n_tables=4, sub_dim=16, seq_len=L,
                       loss=LossConfig(window=4, downstream_len=16,
                                       n_negatives=0))
    fcfg = FinetuneConfig(
        variant="graphsage-lt", seq_len=L, graphsage_dim=16, user_feat_dim=8,
        cand_feat_dim=8, hidden=64, n_cross_layers=2,
        dcat=DCATOptions(rotate_replace=False, skip_last_self_attn=True),
        seq_loss=LossConfig(use_mtl=False, use_ftl=False, n_negatives=0))
    model = PinFMRankingModel.__new__(PinFMRankingModel)
    model.__init__(pcfg, fcfg)
    model.pinfm = PinFMPretrain(pcfg, bb)
    model.dcat = DCAT(model.pinfm.body, fcfg.dcat)
    return model, fcfg


def make_traffic(fcfg, *, n_users, n_batches, reqs_per_batch, n_cand,
                 seed=0):
    """Zipf-ish repeat-user traffic: every batch draws reqs_per_batch users
    from a pool of n_users, so steady state is dominated by repeats."""
    rng = np.random.RandomState(seed)

    def mk(user_seed):
        r = np.random.RandomState(1000 + user_seed)
        return RankRequest(
            seq_ids=r.randint(0, 1500, L),
            seq_actions=r.randint(0, 6, L),
            seq_surfaces=r.randint(0, 3, L),
            cand_ids=rng.randint(0, 1500, n_cand),
            cand_feats=rng.randn(n_cand, fcfg.cand_feat_dim)
            .astype(np.float32),
            user_feats=np.random.RandomState(1000 + user_seed)
            .randn(fcfg.user_feat_dim).astype(np.float32),
            graphsage=rng.randn(n_cand, fcfg.graphsage_dim)
            .astype(np.float32))

    return [[mk(int(u)) for u in rng.randint(0, n_users, reqs_per_batch)]
            for _ in range(n_batches)]


def drive(engine, traffic):
    t0 = time.time()
    n_cand = 0
    for batch in traffic:
        out = engine.score(batch)
        n_cand += sum(len(o) for o in out)
    dt = time.time() - t0
    return n_cand / dt, dt


def main():
    model, fcfg = serving_model()
    params = model.init(jax.random.PRNGKey(0))

    n_batches = 4 if SMOKE else 24
    traffic = make_traffic(fcfg, n_users=6, n_batches=n_batches,
                           reqs_per_batch=6, n_cand=8)

    kw = dict(max_unique=8, max_candidates=64, min_unique=4,
              min_candidates=32)
    uncached = ServingEngine(model, params, **kw)
    cached = ServingEngine(model, params, cache=ContextCache(4096), **kw)
    tu = uncached.warmup()
    tc = cached.warmup()
    print(f"warmup: uncached {tu['executors']} executors {tu['warmup_s']:.1f}s"
          f" | cached {tc['executors']} executors {tc['warmup_s']:.1f}s")

    # prime the cache with one pass, then measure steady-state repeat traffic
    cached.score(traffic[0][:])
    qps_u, dt_u = drive(uncached, traffic)
    qps_c, dt_c = drive(cached, traffic)
    ratio = cached.cache.hits / max(cached.cache.hits + cached.cache.misses, 1)
    print(f"uncached: {qps_u:9.0f} candidates/s ({dt_u * 1e3:6.1f} ms total)")
    print(f"cached:   {qps_c:9.0f} candidates/s ({dt_c * 1e3:6.1f} ms total, "
          f"hit rate {ratio * 100:.0f}%, "
          f"{cached.cache.nbytes / 2**20:.1f} MiB ctx KV)")
    print(f"speedup:  {qps_c / qps_u:.2f}x on repeat-user traffic")

    # recompile accounting on a mixed-shape stream
    rng = np.random.RandomState(7)
    mixed = [t[:int(n)] for t, n in zip(traffic, rng.randint(1, 7, n_batches))]
    for batch in mixed:
        uncached.score(batch)
        cached.score(batch)
    rec_u = uncached.registry.compiles_after_warmup
    rec_c = cached.registry.compiles_after_warmup
    for batch in mixed:                         # second pass
        uncached.score(batch)
        cached.score(batch)
    print(f"recompiles after warmup (mixed-shape stream, 2 passes): "
          f"uncached {uncached.registry.compiles_after_warmup}, "
          f"cached {cached.registry.compiles_after_warmup}")

    assert cached.registry.compiles_after_warmup == 0 == rec_c
    assert uncached.registry.compiles_after_warmup == 0 == rec_u
    assert qps_c > qps_u, (
        f"ContextCache path ({qps_c:.0f}/s) must beat the uncached path "
        f"({qps_u:.0f}/s) on repeat-user traffic")
    print("OK: cached > uncached, zero recompiles after warmup")


if __name__ == "__main__":
    main()
