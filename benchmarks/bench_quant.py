"""Embedding PTQ benchmark (paper §4.2): relative-L2 error, size ratio, and
fused dequant kernel timing.  Paper numbers: 0.45% (int8), 7.8% (int4),
int4 table = 31.25% of fp16."""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row
from repro.kernels import ops as kops
from repro.quant import (compression_ratio, dequantize_table, quantize_table,
                         relative_l2_error)


def main():
    key = jax.random.PRNGKey(0)
    table = (0.02 * jax.random.normal(key, (100_000, 32))).astype(jnp.float16)
    for bits, paper in ((8, 0.0045), (4, 0.078)):
        t0 = time.perf_counter()
        qt = quantize_table(table, bits)
        jax.block_until_ready(qt.packed)
        t_q = (time.perf_counter() - t0) * 1e6
        err = relative_l2_error(table, qt)
        ratio = compression_ratio(table, qt)
        csv_row(f"quant/int{bits}/error", t_q,
                f"rel_l2={err * 100:.3f}%;paper={paper * 100:.2f}%;"
                f"size_ratio={ratio * 100:.2f}%")
        # fused unpack+dequant kernel vs pure-jnp reference
        t0 = time.perf_counter()
        out = kops.int_dequant(qt.packed, qt.scale, qt.bias, bits=bits)
        jax.block_until_ready(out)
        t_k = (time.perf_counter() - t0) * 1e6
        t0 = time.perf_counter()
        ref = dequantize_table(qt, use_kernel=False)
        jax.block_until_ready(ref)
        t_r = (time.perf_counter() - t0) * 1e6
        exact = bool(jnp.all(out == ref))
        csv_row(f"quant/int{bits}/dequant_kernel", t_k,
                f"ref_us={t_r:.0f};exact_match={exact}")


if __name__ == "__main__":
    main()
