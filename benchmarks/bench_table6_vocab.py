"""Table 6: embedding vocabulary (hash rows) scaling.  Paper: Save HIT@3
rises monotonically 20M -> 160M rows.  At our scale: 512 -> 8192 rows over
1.5k items (collision rate is the mechanism: fewer rows => more collisions)."""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import (csv_row, data_cfg, default_fcfg,
                               finetune_and_eval, lift, pinfm_cfg, pretrain)
from repro.data.synthetic import SyntheticActivity

ROWS = [256, 1024, 4096]


def main():
    data = SyntheticActivity(data_cfg())
    results = {}
    for rows in ROWS:
        t0 = time.perf_counter()
        pcfg = pinfm_cfg().replace(rows=rows)
        _, pre, _ = pretrain(pcfg, data=data)
        m, _ = finetune_and_eval(pcfg, default_fcfg(), pre, data=data)
        results[rows] = m
        csv_row(f"table6/rows={rows}", (time.perf_counter() - t0) * 1e6,
                f"save_hit3={m['save_overall']:.4f}")
    base = results[ROWS[0]]
    for rows in ROWS[1:]:
        csv_row(f"table6/lift[rows={rows}]", 0,
                f"save={lift(results[rows]['save_overall'], base['save_overall']):+.2f}%")


if __name__ == "__main__":
    main()
