#!/usr/bin/env python
"""Docs link checker: every relative markdown link must resolve to a file
or directory in the repo.

Scans the repo's *.md files (git-tracked + untracked-but-not-ignored, so
a local virtualenv's bundled READMEs are never scanned; falls back to a
filesystem walk outside a git checkout) for inline links/images
``[text](target)``, skips absolute URLs and pure anchors, and fails with
a per-link report if any target is missing.
Run from anywhere:  python tools/check_docs_links.py
"""
import pathlib
import re
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def md_files():
    try:
        out = subprocess.run(
            ["git", "ls-files", "--cached", "--others", "--exclude-standard",
             "*.md"], cwd=ROOT, capture_output=True, text=True, check=True)
        files = [ROOT / line for line in out.stdout.splitlines() if line]
        if files:
            return files
    except (OSError, subprocess.CalledProcessError):
        pass
    return [p for p in ROOT.rglob("*.md")
            if ".git" not in p.parts and "node_modules" not in p.parts]


def check(md: pathlib.Path):
    errors = []
    for m in LINK.finditer(md.read_text()):
        target = m.group(1)
        if target.startswith(SKIP_PREFIXES):
            continue
        path = target.split("#")[0]
        if not path:
            continue
        resolved = (md.parent / path).resolve()
        if not resolved.exists():
            errors.append(f"{md.relative_to(ROOT)}: broken link -> {target}")
    return errors


def main():
    files = md_files()
    errors = [e for md in sorted(files) for e in check(md)]
    for e in errors:
        print(e, file=sys.stderr)
    print(f"checked {len(files)} markdown files: "
          f"{'FAIL' if errors else 'ok'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
