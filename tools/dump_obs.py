#!/usr/bin/env python
"""Pretty-print (and validate) serving observability artifacts.

Takes any mix of Chrome trace-event JSON files (exported by
``engine.obs.export_trace`` / ``Tracer.export``) and Prometheus text
files (``engine.obs.export_prometheus``), sniffing the format per file:

  * trace JSON -> event count, dropped-event count, per-track span
    totals (count + total duration), slowest spans;
  * Prometheus text -> every non-histogram sample, plus one line per
    histogram label set with count / p50 / p99 (read from the exported
    ``_p50``/``_p99`` gauges).

With ``--merge``, the inputs are instead per-worker metrics SNAPSHOT
JSON files (``MetricsRegistry.snapshot()`` / a cluster worker's
``obs_snapshot``) and the tool emits ONE Prometheus text exposition on
stdout (``-o FILE`` also writes it): every input's series re-labelled
with ``worker="<file stem>"`` plus an unlabelled aggregate series per
metric (counters/gauges sum; histogram bucket counts add, quantiles
recomputed exactly from the merged buckets the way
``repro.obs.metrics.Histogram`` computes them — the pXX is the upper
bound of the bucket holding rank ``ceil(q*count)``, and a rank landing
in the overflow bucket reports the top observed bound).

Exits non-zero when a file is malformed — a trace that is not loadable
trace-event JSON (missing ``traceEvents``, events missing ph/ts, a
complete event missing dur), a metrics file with an unparseable
sample line, or a ``--merge`` snapshot that is not a flat
name->scalar|histogram-dict mapping — so CI can gate on "the exporters
produce artifacts the tools can actually consume":

    python examples/serve_two_stage.py --smoke --trace-out /tmp/t.json
    python tools/dump_obs.py /tmp/t.json /tmp/t.json.prom
    python tools/dump_obs.py --merge /tmp/w0.json /tmp/w1.json -o /tmp/all.prom
"""
import json
import math
import os
import re
import sys
from collections import defaultdict

# Prometheus sample: name{optional labels} value
_REQUIRED_PH_FIELDS = {"X": ("dur",), "i": (), "M": (), "C": ()}


def fail(msg: str) -> None:
    print(f"dump_obs: MALFORMED: {msg}", file=sys.stderr)
    sys.exit(1)


def dump_trace(path: str, doc) -> None:
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        fail(f"{path}: no traceEvents key (not Chrome trace-event JSON)")
    events = doc["traceEvents"]
    if not isinstance(events, list):
        fail(f"{path}: traceEvents is not a list")
    tracks = {}
    per_track = defaultdict(lambda: [0, 0.0])     # tid -> [count, dur_us]
    spans = []
    for i, ev in enumerate(events):
        if not isinstance(ev, dict) or "ph" not in ev or "name" not in ev:
            fail(f"{path}: event {i} missing ph/name: {ev!r}")
        ph = ev["ph"]
        if ph == "M":
            if ev["name"] == "thread_name":
                tracks[ev.get("tid", 0)] = ev["args"]["name"]
            continue
        if "ts" not in ev:
            fail(f"{path}: event {i} ({ev['name']!r}) missing ts")
        for field in _REQUIRED_PH_FIELDS.get(ph, ()):
            if field not in ev:
                fail(f"{path}: {ph!r} event {i} ({ev['name']!r}) "
                     f"missing {field}")
        if ph == "X":
            t = per_track[ev.get("tid", 0)]
            t[0] += 1
            t[1] += ev["dur"]
            spans.append((ev["dur"], ev["name"], ev.get("tid", 0)))
    other = doc.get("otherData", {})
    print(f"== trace {path}: {len(events)} events, "
          f"{len(tracks)} named tracks, "
          f"dropped={other.get('dropped_events', 0)} "
          f"capacity={other.get('capacity', '?')}")
    for tid in sorted(per_track):
        n, dur = per_track[tid]
        print(f"  track {tracks.get(tid, tid)!s:<22} {n:5d} spans  "
              f"{dur / 1e3:10.2f} ms total")
    for dur, name, tid in sorted(spans, reverse=True)[:5]:
        print(f"  slowest: {name:<18} {dur / 1e3:10.2f} ms  "
              f"on {tracks.get(tid, tid)}")


def dump_prometheus(path: str, text: str) -> None:
    hist = defaultdict(dict)       # (metric base, labels) -> {suffix: value}
    plain = []
    for ln, line in enumerate(text.splitlines(), 1):
        if not line.strip() or line.startswith("#"):
            continue
        try:
            name_part, value = line.rsplit(" ", 1)
            float(value)           # +Inf / nan are valid Prometheus floats
        except ValueError:
            fail(f"{path}:{ln}: unparseable sample line: {line!r}")
        name = name_part.split("{", 1)[0]
        labels = (name_part[len(name):] if "{" in name_part else "")
        for suffix in ("_bucket", "_sum", "_count", "_p50", "_p99"):
            if name.endswith(suffix):
                base = name[: -len(suffix)]
                if suffix == "_bucket":    # drop the le label for grouping
                    labels = labels.replace("{", "").replace("}", "")
                    labels = ",".join(p for p in labels.split(",")
                                      if not p.startswith('le='))
                    labels = "{" + labels + "}" if labels else ""
                hist[(base, labels)][suffix] = value
                break
        else:
            plain.append((name + labels, value))
    print(f"== metrics {path}: {len(plain)} samples, "
          f"{len(hist)} histogram series")
    for name, value in plain:
        print(f"  {name:<58} {value}")
    for (base, labels), parts in sorted(hist.items()):
        if "_count" not in parts:
            continue
        print(f"  {base + labels:<58} count={parts['_count']} "
              f"p50={parts.get('_p50', 'n/a')} "
              f"p99={parts.get('_p99', 'n/a')}")


# ---------------------------------------------------------------------------
# --merge: per-worker snapshot JSONs -> one labelled + aggregated exposition
# ---------------------------------------------------------------------------

_LABEL_RE = re.compile(r'(\w+)="([^"]*)"')


def _parse_series_key(path: str, key: str):
    """``name{k="v",...}`` -> (name, ((k, v), ...)) — the snapshot key
    format ``MetricsRegistry.snapshot`` writes."""
    name, brace, rest = key.partition("{")
    if not name or any(c in name for c in "{} \t"):
        fail(f"{path}: bad series key {key!r}")
    if not brace:
        return name, ()
    if not rest.endswith("}"):
        fail(f"{path}: bad series key {key!r}")
    return name, tuple(_LABEL_RE.findall(rest[:-1]))


def _fmt_labels(labels) -> str:
    if not labels:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in labels) + "}"


class _MergedHist:
    """Histogram folded from snapshot bucket dicts — per-bound counts
    over the union of observed bounds, plus the overflow remainder."""

    def __init__(self):
        self.per_bound = defaultdict(int)   # float bound -> bucket count
        self.overflow = 0
        self.count = 0
        self.sum = 0.0

    def add(self, path: str, snap: dict) -> None:
        try:
            count, total = int(snap["count"]), float(snap["sum"])
            buckets = snap["buckets"]
            bounds = [(float(le), int(cum)) for le, cum in buckets.items()]
        except (KeyError, TypeError, ValueError):
            fail(f"{path}: bad histogram snapshot {snap!r}")
        bounds.sort()
        prev = 0
        for b, cum in bounds:
            if cum < prev:
                fail(f"{path}: non-cumulative histogram buckets {snap!r}")
            self.per_bound[b] += cum - prev
            prev = cum
        if count < prev:
            fail(f"{path}: histogram count {count} < bucket total {prev}")
        self.overflow += count - prev
        self.count += count
        self.sum += total

    def quantile(self, q: float) -> float:
        """Exactly ``Histogram.quantile`` over the merged buckets: the
        inclusive upper bound of the bucket holding rank ceil(q*count);
        an overflow rank reports the top observed bound."""
        if self.count == 0:
            return float("nan")
        rank = max(1, math.ceil(q * self.count))
        bounds = sorted(self.per_bound)
        cum = 0
        for b in bounds:
            cum += self.per_bound[b]
            if cum >= rank:
                return b
        return bounds[-1] if bounds else float("nan")

    def emit(self, full: str, labels, lines) -> None:
        ls = _fmt_labels(labels)
        cum = 0
        for b in sorted(self.per_bound):
            c = self.per_bound[b]
            cum += c
            if c:
                lines.append(f"{full}_bucket"
                             f"{_fmt_labels(labels + (('le', repr(b)),))} "
                             f"{cum}")
        lines.append(f"{full}_bucket{_fmt_labels(labels + (('le', '+Inf'),))} "
                     f"{self.count}")
        lines.append(f"{full}_sum{ls} {repr(self.sum)}")
        lines.append(f"{full}_count{ls} {self.count}")
        if self.count:
            lines.append(f"{full}_p50{ls} {repr(float(self.quantile(0.5)))}")
            lines.append(f"{full}_p99{ls} {repr(float(self.quantile(0.99)))}")


def merge_snapshots(paths):
    """-> Prometheus text: each input's series labelled
    ``worker="<stem>"`` + one aggregate (unlabelled) series per metric."""
    series = {}          # (name, labels) -> scalar | _MergedHist
    order = []
    kinds = {}           # name -> "histogram" | "untyped"

    def slot(name, labels, is_hist):
        key = (name, labels)
        if key not in series:
            series[key] = _MergedHist() if is_hist else 0
            order.append(key)
        return key

    for path in paths:
        try:
            with open(path) as f:
                doc = json.load(f)
        except OSError as e:
            fail(f"{path}: {e}")
        except json.JSONDecodeError as e:
            fail(f"{path}: invalid JSON: {e}")
        if not isinstance(doc, dict):
            fail(f"{path}: snapshot is not an object")
        worker = os.path.splitext(os.path.basename(path))[0]
        for key, value in doc.items():
            name, labels = _parse_series_key(path, key)
            is_hist = isinstance(value, dict)
            if not is_hist and not isinstance(value, (int, float)):
                fail(f"{path}: {key!r}: value is neither scalar nor "
                     f"histogram dict: {value!r}")
            if kinds.setdefault(name, "histogram" if is_hist
                                else "untyped") != (
                    "histogram" if is_hist else "untyped"):
                fail(f"{path}: {name!r} is a histogram in one snapshot "
                     "and a scalar in another")
            for lab in (labels + (("worker", worker),), labels):
                k = slot(name, lab, is_hist)
                if is_hist:
                    series[k].add(path, value)
                else:
                    series[k] += value
    lines = []
    for name in sorted({n for n, _ in order}):
        lines.append(f"# TYPE {name} {kinds[name]}")
        for key in order:
            if key[0] != name:
                continue
            m = series[key]
            if isinstance(m, _MergedHist):
                m.emit(name, key[1], lines)
            else:
                lines.append(f"{name}{_fmt_labels(key[1])} "
                             f"{m if isinstance(m, int) else repr(float(m))}")
    return "\n".join(lines) + ("\n" if lines else "")


def main(argv):
    if not argv:
        print(__doc__)
        return 2
    if argv[0] == "--merge":
        rest, out = argv[1:], None
        if "-o" in rest:
            i = rest.index("-o")
            if i + 1 >= len(rest):
                fail("-o needs a path")
            out = rest[i + 1]
            rest = rest[:i] + rest[i + 2:]
        if not rest:
            fail("--merge needs at least one snapshot JSON")
        text = merge_snapshots(rest)
        if out is not None:
            with open(out, "w") as f:
                f.write(text)
        sys.stdout.write(text)
        return 0
    for path in argv:
        try:
            with open(path) as f:
                text = f.read()
        except OSError as e:
            fail(f"{path}: {e}")
        stripped = text.lstrip()
        if stripped.startswith("{"):
            try:
                doc = json.loads(text)
            except json.JSONDecodeError as e:
                fail(f"{path}: invalid JSON: {e}")
            dump_trace(path, doc)
        else:
            dump_prometheus(path, text)
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
