#!/usr/bin/env python
"""Pretty-print (and validate) serving observability artifacts.

Takes any mix of Chrome trace-event JSON files (exported by
``engine.obs.export_trace`` / ``Tracer.export``) and Prometheus text
files (``engine.obs.export_prometheus``), sniffing the format per file:

  * trace JSON -> event count, dropped-event count, per-track span
    totals (count + total duration), slowest spans;
  * Prometheus text -> every non-histogram sample, plus one line per
    histogram label set with count / p50 / p99 (read from the exported
    ``_p50``/``_p99`` gauges).

Exits non-zero when a file is malformed — a trace that is not loadable
trace-event JSON (missing ``traceEvents``, events missing ph/ts, a
complete event missing dur) or a metrics file with an unparseable
sample line — so CI can gate on "the exporters produce artifacts the
tools can actually consume":

    python examples/serve_two_stage.py --smoke --trace-out /tmp/t.json
    python tools/dump_obs.py /tmp/t.json /tmp/t.json.prom
"""
import json
import sys
from collections import defaultdict

# Prometheus sample: name{optional labels} value
_REQUIRED_PH_FIELDS = {"X": ("dur",), "i": (), "M": (), "C": ()}


def fail(msg: str) -> None:
    print(f"dump_obs: MALFORMED: {msg}", file=sys.stderr)
    sys.exit(1)


def dump_trace(path: str, doc) -> None:
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        fail(f"{path}: no traceEvents key (not Chrome trace-event JSON)")
    events = doc["traceEvents"]
    if not isinstance(events, list):
        fail(f"{path}: traceEvents is not a list")
    tracks = {}
    per_track = defaultdict(lambda: [0, 0.0])     # tid -> [count, dur_us]
    spans = []
    for i, ev in enumerate(events):
        if not isinstance(ev, dict) or "ph" not in ev or "name" not in ev:
            fail(f"{path}: event {i} missing ph/name: {ev!r}")
        ph = ev["ph"]
        if ph == "M":
            if ev["name"] == "thread_name":
                tracks[ev.get("tid", 0)] = ev["args"]["name"]
            continue
        if "ts" not in ev:
            fail(f"{path}: event {i} ({ev['name']!r}) missing ts")
        for field in _REQUIRED_PH_FIELDS.get(ph, ()):
            if field not in ev:
                fail(f"{path}: {ph!r} event {i} ({ev['name']!r}) "
                     f"missing {field}")
        if ph == "X":
            t = per_track[ev.get("tid", 0)]
            t[0] += 1
            t[1] += ev["dur"]
            spans.append((ev["dur"], ev["name"], ev.get("tid", 0)))
    other = doc.get("otherData", {})
    print(f"== trace {path}: {len(events)} events, "
          f"{len(tracks)} named tracks, "
          f"dropped={other.get('dropped_events', 0)} "
          f"capacity={other.get('capacity', '?')}")
    for tid in sorted(per_track):
        n, dur = per_track[tid]
        print(f"  track {tracks.get(tid, tid)!s:<22} {n:5d} spans  "
              f"{dur / 1e3:10.2f} ms total")
    for dur, name, tid in sorted(spans, reverse=True)[:5]:
        print(f"  slowest: {name:<18} {dur / 1e3:10.2f} ms  "
              f"on {tracks.get(tid, tid)}")


def dump_prometheus(path: str, text: str) -> None:
    hist = defaultdict(dict)       # (metric base, labels) -> {suffix: value}
    plain = []
    for ln, line in enumerate(text.splitlines(), 1):
        if not line.strip() or line.startswith("#"):
            continue
        try:
            name_part, value = line.rsplit(" ", 1)
            float(value)           # +Inf / nan are valid Prometheus floats
        except ValueError:
            fail(f"{path}:{ln}: unparseable sample line: {line!r}")
        name = name_part.split("{", 1)[0]
        labels = (name_part[len(name):] if "{" in name_part else "")
        for suffix in ("_bucket", "_sum", "_count", "_p50", "_p99"):
            if name.endswith(suffix):
                base = name[: -len(suffix)]
                if suffix == "_bucket":    # drop the le label for grouping
                    labels = labels.replace("{", "").replace("}", "")
                    labels = ",".join(p for p in labels.split(",")
                                      if not p.startswith('le='))
                    labels = "{" + labels + "}" if labels else ""
                hist[(base, labels)][suffix] = value
                break
        else:
            plain.append((name + labels, value))
    print(f"== metrics {path}: {len(plain)} samples, "
          f"{len(hist)} histogram series")
    for name, value in plain:
        print(f"  {name:<58} {value}")
    for (base, labels), parts in sorted(hist.items()):
        if "_count" not in parts:
            continue
        print(f"  {base + labels:<58} count={parts['_count']} "
              f"p50={parts.get('_p50', 'n/a')} "
              f"p99={parts.get('_p99', 'n/a')}")


def main(argv):
    if not argv:
        print(__doc__)
        return 2
    for path in argv:
        try:
            with open(path) as f:
                text = f.read()
        except OSError as e:
            fail(f"{path}: {e}")
        stripped = text.lstrip()
        if stripped.startswith("{"):
            try:
                doc = json.loads(text)
            except json.JSONDecodeError as e:
                fail(f"{path}: invalid JSON: {e}")
            dump_trace(path, doc)
        else:
            dump_prometheus(path, text)
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
